//! Vendored interface stub for the `xla` crate (xla-rs PJRT bindings).
//!
//! The offline build environment does not carry the xla_extension C++
//! runtime, so this crate provides the exact API surface the coordinator
//! uses, with real in-memory [`Literal`] semantics (those are pure data)
//! and compile/execute entry points that fail with an actionable error.
//! Everything that never touches PJRT — the native quantizers, the whole
//! engine/scheduler layer, linalg, unit + property tests — builds and
//! runs against this stub; artifact execution requires swapping in the
//! real crate (root Cargo.toml `[dependencies] xla`).
//!
//! All types here are plain data (no FFI handles), so they are `Send +
//! Sync` — which the coordinator's `Sync` `Runtime` relies on.

use std::fmt;
use std::path::Path;

/// Stub error type; implements `std::error::Error` so `?` converts into
/// the caller's error type.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what} requires the real xla_extension runtime — this build links \
         the vendored interface stub (see root Cargo.toml)"
    )))
}

/// Element types the coordinator moves through literals.
pub trait NativeType: Copy {
    fn wrap(v: &[Self]) -> Data;
    fn unwrap(d: &Data) -> Option<Vec<Self>>;
}

#[derive(Debug, Clone)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

impl NativeType for f32 {
    fn wrap(v: &[f32]) -> Data {
        Data::F32(v.to_vec())
    }
    fn unwrap(d: &Data) -> Option<Vec<f32>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: &[i32]) -> Data {
        Data::I32(v.to_vec())
    }
    fn unwrap(d: &Data) -> Option<Vec<i32>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// An in-memory literal: element buffer + dims. Fully functional (these
/// are pure data and the unit tests exercise them).
#[derive(Debug, Clone)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { data: T::wrap(v), dims: vec![v.len() as i64] }
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(v) => v.len(),
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let numel: i64 = dims.iter().product();
        if numel as usize != self.element_count() {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .ok_or_else(|| Error("literal element type mismatch".into()))
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        self.to_vec::<T>()?
            .first()
            .copied()
            .ok_or_else(|| Error("empty literal".into()))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(v) => Ok(v),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }
}

impl From<f32> for Literal {
    fn from(v: f32) -> Literal {
        Literal { data: Data::F32(vec![v]), dims: vec![] }
    }
}

/// Parsed HLO module handle (opaque in the stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable("parsing HLO text artifacts")
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client handle. Construction succeeds (so pipelines can be built
/// and introspected); compilation/execution report the stub.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compiling computations")
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("executing computations")
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("device-to-host transfers")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_from_f32() {
        let l = Literal::from(0.05f32);
        assert_eq!(l.get_first_element::<f32>().unwrap(), 0.05);
    }

    #[test]
    fn runtime_surface_reports_stub() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "cpu-stub");
        let err = client.compile(&XlaComputation::from_proto(&HloModuleProto)).unwrap_err();
        assert!(err.to_string().contains("xla stub"));
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
