//! Vendored minimal stand-in for the `anyhow` crate (the build
//! environment is offline — see the root Cargo.toml note). Implements
//! exactly the surface this repository uses:
//!
//! * [`Error`] / [`Result`] with context-chain display (`{e}` prints the
//!   outermost message, `{e:#}` the full `outer: inner: ...` chain,
//!   `{e:?}` an anyhow-style "Caused by" listing),
//! * blanket `From<E: std::error::Error>` so `?` converts any std error,
//! * the [`Context`] extension trait on `Result` and `Option`,
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros.
//!
//! Drop-in replaceable by the real crate: no API here deviates from
//! anyhow 1.x semantics for the forms used.

use std::fmt;

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-carrying error. Deliberately does NOT implement
/// `std::error::Error`, which is what makes the blanket `From` sound
/// (same trick as the real anyhow).
pub struct Error {
    /// context frames, outermost first
    frames: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { frames: vec![m.to_string()] }
    }

    /// Wrap with an outer context frame (used by [`Context`]).
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.frames.insert(0, c.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.frames.iter().map(|s| s.as_str())
    }

    /// The outermost message (what `{}` prints).
    pub fn root_cause_message(&self) -> &str {
        self.frames.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.frames.first().map(|s| s.as_str()).unwrap_or(""))?;
        if f.alternate() {
            for frame in &self.frames[1..] {
                write!(f, ": {frame}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.frames.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.frames.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for frame in &self.frames[1..] {
                write!(f, "\n    {frame}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut frames = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            frames.push(s.to_string());
            src = s.source();
        }
        Error { frames }
    }
}

/// Context extension for `Result` and `Option`, mirroring anyhow's.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(format!(
                "Condition failed: `{}`",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .context("loading manifest")
            .unwrap_err();
        assert_eq!(e.to_string(), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: missing file");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("no value").unwrap_err();
        assert_eq!(e.to_string(), "no value");
    }

    #[test]
    fn macros_work() {
        fn inner(fail: bool, n: usize) -> Result<usize> {
            ensure!(n < 10, "n too big: {n}");
            ensure!(n != 7);
            if fail {
                bail!("failing as requested");
            }
            Ok(n)
        }
        assert_eq!(inner(false, 3).unwrap(), 3);
        assert_eq!(inner(true, 3).unwrap_err().to_string(), "failing as requested");
        assert_eq!(inner(false, 12).unwrap_err().to_string(), "n too big: 12");
        assert!(inner(false, 7).unwrap_err().to_string().contains("n != 7"));
        let e = anyhow!("x = {}", 5);
        assert_eq!(e.to_string(), "x = 5");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }
}
