//! Table 1's runtime row: wall-clock of each Beacon variant relative to
//! GPTQ on the same machine and calibration set (the paper reports
//! 1–1.5× w/o EC, 2–2.5× w/ EC, 2–3× w/ LN) — plus the PJRT-Pallas vs
//! native kernel backend comparison for §Perf.

use beacon_ptq::config::QuantConfig;
use beacon_ptq::coordinator::{experiments, KernelBackend, Pipeline};
use beacon_ptq::quant::alphabet::BitWidth;

fn main() {
    let mut pipe = match Pipeline::from_artifacts("artifacts", "tiny-sim") {
        Ok(p) => p,
        Err(e) => {
            eprintln!("skipping runtime bench (artifacts missing): {e:#}");
            return;
        }
    };

    let table = experiments::runtime_row(&mut pipe, BitWidth::B2, 4)
        .expect("runtime row");
    println!("{}", table.render());

    // backend comparison: the same 2-bit run through the AOT Pallas kernel
    // vs the native twin
    for backend in [KernelBackend::Pjrt, KernelBackend::Native] {
        pipe.backend = backend;
        let qc = QuantConfig { bits: 2.0, loops: 4, ..QuantConfig::default() };
        let t = std::time::Instant::now();
        let report = pipe.quantize_cfg(&qc).expect("quantize");
        println!(
            "backend {:?}: quantize {:.2}s (top-1 {:.2}%)",
            backend,
            t.elapsed().as_secs_f64(),
            report.top1 * 100.0
        );
    }
    let stats = pipe.runtime.stats();
    println!(
        "runtime totals: {} compilations {:.0} ms, {} executions {:.0} ms",
        stats.compilations, stats.compile_ms, stats.executions, stats.exec_ms
    );
}
