//! Regenerates the paper's evaluation tables end-to-end (DESIGN.md §5):
//! Table 1 (Beacon variants × bit widths), Table 2 (vs GPTQ/COMQ),
//! F1 (objective vs sweep count), A1 (calibration size), A2 (EC per-layer
//! errors). Requires `make artifacts`.
//!
//! This is a *reporting* bench: it prints the tables EXPERIMENTS.md quotes.

use beacon_ptq::coordinator::{experiments, Pipeline};
use beacon_ptq::quant::alphabet::BitWidth;

fn main() {
    let mut pipe = match Pipeline::from_artifacts("artifacts", "tiny-sim") {
        Ok(p) => p,
        Err(e) => {
            eprintln!("skipping table benches (artifacts missing): {e:#}");
            return;
        }
    };

    let grid = vec![
        (BitWidth::B158, 6usize),
        (BitWidth::B2, 4),
        (BitWidth::B258, 4),
        (BitWidth::B3, 6),
        (BitWidth::B4, 4),
    ];
    let t0 = std::time::Instant::now();
    let (t1, _) = experiments::table1(&mut pipe, &grid).expect("table1");
    println!("{}", t1.render());
    println!("(table 1 wall: {:.1}s)\n", t0.elapsed().as_secs_f64());

    let t0 = std::time::Instant::now();
    let grid2 = vec![(BitWidth::B2, 4usize), (BitWidth::B3, 6), (BitWidth::B4, 4)];
    let (t2, _) = experiments::table2(&mut pipe, &grid2).expect("table2");
    println!("{}", t2.render());
    println!("(table 2 wall: {:.1}s)\n", t0.elapsed().as_secs_f64());

    let f1 = experiments::convergence(&mut pipe, 8).expect("convergence");
    println!("{}", f1.render());

    let a1 = experiments::ablate_calib(&mut pipe, &[8, 16, 32, 64, 128])
        .expect("ablate_calib");
    println!("{}", a1.render());

    let a2 = experiments::ablate_ec(&mut pipe, BitWidth::B2).expect("ablate_ec");
    println!("{}", a2.render());
}
