//! Microbenchmarks of the quantization algorithms (native path): the
//! per-channel Beacon sweep across layer sizes / bit widths / sweep
//! counts, and the per-layer cost of every baseline. These are the
//! numbers behind EXPERIMENTS.md §Perf (L3).
//!
//! Besides the human-readable report, this bench writes
//! `BENCH_quant.json` — a machine-readable `method × bits × threads →
//! ns/channel` record — so the perf trajectory is tracked across PRs.
//! The beacon rows time the *prefactored* layer sweep (QR hoisted out),
//! i.e. exactly the channel fan-out the engine scheduler parallelizes.
//!
//! The bench also runs with the tracking allocator installed and writes
//! `BENCH_memory.json` (`method × bits → peak heap bytes` per layer
//! quantize) for the perf gate's memory section. The allocator costs a
//! few relaxed atomic ops per allocation; the kernels are
//! allocation-light in the hot loop, so the latency rows stay
//! comparable with earlier records.

use beacon_ptq::config::{PlanBuilder, QuantConfig, SearchSpace};
use beacon_ptq::coordinator::planner::{search_plan, LayerProbe};
use beacon_ptq::data::rng::SplitMix64;
use beacon_ptq::linalg::{qr_factor, Matrix};
use beacon_ptq::obs::{self, memory, HistSummary, TrackingAlloc};
use beacon_ptq::quant::alphabet::{alphabet, BitWidth};
use beacon_ptq::quant::beacon::{
    beacon_channel, beacon_layer, beacon_layer_prefactored, BeaconOpts,
};
use beacon_ptq::quant::engine::{self, LayerCtx, Quantizer as _};
use beacon_ptq::quant::{
    comq_layer, comq_layer_threads, gptq_layer, rtn_layer, rtn_layer_threads,
};
use beacon_ptq::util::bench::{bench, black_box};
use beacon_ptq::util::prop::Gen;

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

fn case(seed: u64, m: usize, n: usize, np: usize) -> (Matrix, Matrix) {
    let mut g = Gen { rng: SplitMix64::new(seed) };
    let x = Matrix::from_vec(m, n, g.vec_normal(m * n, 1.0));
    let w = Matrix::from_vec(n, np, g.vec_normal(n * np, 0.3));
    (x, w)
}

struct Rec {
    method: &'static str,
    bits: String,
    threads: usize,
    median_ns: u128,
    ns_per_channel: f64,
    /// per-channel latency distribution from the obs recorder
    /// (`engine.channels.item_ns`); None for serial-only kernels
    /// that never enter the channel fan (gptq).
    chan: Option<HistSummary>,
}

/// Drain the recorder's per-channel histogram for the row just timed.
fn chan_summary() -> Option<HistSummary> {
    obs::snapshot()
        .hists
        .get("engine.channels.item_ns")
        .map(|h| h.summary())
}

fn main() {
    println!("== quant kernel microbenches (native) ==\n");

    // --- beacon_channel across N (the inner hot path) ---------------------
    for &n in &[64usize, 128, 256] {
        let (x, w) = case(1, 4 * n, n, 1);
        let f = qr_factor(&x, &x);
        let l_cols = f.l.columns();
        let lt_cols = f.r.columns();
        let nnz: Vec<usize> = (0..n).map(|t| t + 1).collect();
        let wcol = w.col(0);
        let a = alphabet(BitWidth::B2);
        bench(&format!("beacon_channel N={n} 2-bit K=4"), 2, 10, || {
            black_box(beacon_channel(&l_cols, &lt_cols, &nnz, &wcol, &a, 4));
        });
    }

    // --- beacon_channel across bit widths ----------------------------------
    let n = 128;
    let (x, w) = case(2, 4 * n, n, 1);
    let f = qr_factor(&x, &x);
    let l_cols = f.l.columns();
    let lt_cols = f.r.columns();
    let nnz: Vec<usize> = (0..n).map(|t| t + 1).collect();
    let wcol = w.col(0);
    for bits in BitWidth::ALL {
        let a = alphabet(bits);
        bench(&format!("beacon_channel N={n} {} K=4", bits.label()), 2, 10, || {
            black_box(beacon_channel(&l_cols, &lt_cols, &nnz, &wcol, &a, 4));
        });
    }

    // --- sweep count scaling ------------------------------------------------
    for &loops in &[0usize, 2, 4, 8] {
        let a = alphabet(BitWidth::B2);
        bench(&format!("beacon_channel N={n} 2-bit K={loops}"), 2, 10, || {
            black_box(beacon_channel(&l_cols, &lt_cols, &nnz, &wcol, &a, loops));
        });
    }

    // --- whole-layer comparison across methods ------------------------------
    println!();
    let (x, w) = case(3, 1088, 64, 192); // tiny-sim qkv shape at full calib
    let a2 = alphabet(BitWidth::B2);
    bench("layer 64x192 beacon (K=4)", 1, 5, || {
        black_box(beacon_layer(&x, &x, &w, &a2, &BeaconOpts::default()));
    });
    bench("layer 64x192 beacon+centering", 1, 5, || {
        black_box(beacon_layer(
            &x,
            &x,
            &w,
            &a2,
            &BeaconOpts { loops: 4, centering: true, ..Default::default() },
        ));
    });
    bench("layer 64x192 gptq", 1, 5, || {
        black_box(gptq_layer(&x, &w, BitWidth::B2, 0.01));
    });
    bench("layer 64x192 comq (K=4)", 1, 5, || {
        black_box(comq_layer(&x, &w, BitWidth::B2, 4));
    });
    bench("layer 64x192 rtn", 1, 5, || {
        black_box(rtn_layer(&w, BitWidth::B2));
    });

    // --- machine-readable perf record: BENCH_quant.json ---------------------
    println!("\n== thread-scaling sweep (method × bits × threads) ==");
    // Record per-channel latency histograms for each row; reset before
    // every timed section so a record's p50/p95/p99 covers only its own
    // iterations.
    obs::enable();
    let (m, nn, np) = (512usize, 64usize, 128usize);
    let (x, w) = case(7, m, nn, np);
    let f = qr_factor(&x, &x);
    let thread_grid = [1usize, 2, 4];
    let mut recs: Vec<Rec> = Vec::new();
    let mut push = |method: &'static str, bits: BitWidth, threads, median_ns, chan| {
        recs.push(Rec {
            method,
            bits: bits.label(),
            threads,
            median_ns,
            ns_per_channel: median_ns as f64 / np as f64,
            chan,
        });
    };
    for &bits in &[BitWidth::B2, BitWidth::B4] {
        let a = alphabet(bits);
        for &threads in &thread_grid {
            let opts = BeaconOpts { loops: 4, centering: false, threads };
            obs::reset();
            let r = bench(
                &format!("beacon sweep {nn}x{np} {} t={threads}", bits.label()),
                1,
                3,
                || {
                    black_box(beacon_layer_prefactored(
                        &f.l, &f.r, &x, &x, &w, &a, &opts,
                    ));
                },
            );
            push("beacon", bits, threads, r.median_ns, chan_summary());
        }
    }
    for &threads in &thread_grid {
        obs::reset();
        let r = bench(&format!("rtn {nn}x{np} 2-bit t={threads}"), 1, 3, || {
            black_box(rtn_layer_threads(&w, BitWidth::B2, threads));
        });
        push("rtn", BitWidth::B2, threads, r.median_ns, chan_summary());
        obs::reset();
        let r = bench(&format!("comq {nn}x{np} 2-bit K=4 t={threads}"), 1, 3, || {
            black_box(comq_layer_threads(&x, &w, BitWidth::B2, 4, threads));
        });
        push("comq", BitWidth::B2, threads, r.median_ns, chan_summary());
    }
    // GPTQ's row recursion is serial on the channel axis: one row, t=1
    obs::reset();
    let r = bench(&format!("gptq {nn}x{np} 2-bit t=1"), 1, 3, || {
        black_box(gptq_layer(&x, &w, BitWidth::B2, 0.01));
    });
    push("gptq", BitWidth::B2, 1, r.median_ns, chan_summary());

    // --- scenario rows: the grouped / asymmetric / outlier-sidecar
    // quantization paths through the engine quantizer — the per-group
    // restricted sweeps and sidecar bookkeeping priced against the
    // dense rows above (same layer, same bit width) ----------------------
    println!("\n== scenario sweep (grouped / asymmetric / outliers) ==");
    {
        use beacon_ptq::config::Method;
        let scenarios: [(&'static str, Method, usize, bool, usize); 3] = [
            ("beacon-g16-asym", Method::Beacon, 16, true, 0),
            ("beacon-g16-k2", Method::Beacon, 16, false, 2),
            ("rtn-g16-asym-k2", Method::Rtn, 16, true, 2),
        ];
        for &(name, method, gsz, asym, k) in &scenarios {
            for &threads in &thread_grid {
                let qc = QuantConfig {
                    method,
                    bits: 2.0,
                    loops: 4,
                    threads,
                    group_size: gsz,
                    asymmetric: asym,
                    outlier_k: k,
                    ..QuantConfig::default()
                };
                let q = method.quantizer(BitWidth::B2, &qc);
                obs::reset();
                let r = bench(&format!("{name} {nn}x{np} 2-bit t={threads}"), 1, 3, || {
                    black_box(
                        q.quantize_layer(&LayerCtx::plain(&x, &w, threads)).unwrap(),
                    );
                });
                push(name, BitWidth::B2, threads, r.median_ns, chan_summary());
            }
        }
    }

    // --- mixed-plan rows: heterogeneous per-layer method×bits through the
    // engine scheduler, exactly as Pipeline::quantize(&QuantPlan) fans it
    // (attention at beacon:2, MLP at comq:4 — one tiny-sim block) --------
    println!("\n== mixed plan (beacon:2 attn + comq:4 mlp) ==");
    let lnames: Vec<String> = vec![
        "blocks.0.qkv.w".into(),
        "blocks.0.proj.w".into(),
        "blocks.0.fc1.w".into(),
        "blocks.0.fc2.w".into(),
    ];
    let shapes = [(512usize, 64usize, 192usize), (512, 64, 64), (512, 64, 128), (512, 128, 64)];
    let cases: Vec<(Matrix, Matrix)> = shapes
        .iter()
        .enumerate()
        .map(|(i, &(m, n, np))| case(40 + i as u64, m, n, np))
        .collect();
    let mixed_plan = PlanBuilder::uniform(&QuantConfig {
        bits: 2.0,
        loops: 4,
        ..QuantConfig::default()
    })
    .override_layers("blocks.*.fc?.w", "comq:4")
    .unwrap()
    .build(&lnames)
    .unwrap();
    let total_channels: usize = shapes.iter().map(|&(_, _, np)| np).sum();
    for &threads in &thread_grid {
        let quantizers: Vec<_> = mixed_plan
            .assignments
            .iter()
            .map(|a| a.quantizer(&mixed_plan.base))
            .collect();
        let sched = engine::plan(
            threads,
            cases.len(),
            quantizers.iter().all(|q| q.parallel_safe()),
        );
        obs::reset();
        let r = bench(&format!("mixed plan 4 layers t={threads}"), 1, 3, || {
            let out = engine::run_layers(sched, cases.len(), |li| {
                let (x, w) = &cases[li];
                quantizers[li].quantize_layer(&LayerCtx::plain(x, w, sched.channel_threads))
            })
            .unwrap();
            black_box(out);
        });
        recs.push(Rec {
            method: "mixed-plan",
            bits: "2+4".to_string(),
            threads,
            median_ns: r.median_ns,
            ns_per_channel: r.median_ns as f64 / total_channels as f64,
            chan: chan_summary(),
        });
    }

    // --- auto-plan search rows: the loss-aware planner's probe sweep +
    // greedy allocation over the same 4 layers (probes fan through the
    // engine scheduler, so search time scales with the thread budget
    // like any other layer fan) ------------------------------------------
    println!("\n== auto-plan search (beacon probes at 2/4 bits) ==");
    let grams: Vec<Matrix> = cases.iter().map(|(x, _)| x.gram()).collect();
    let numels: Vec<usize> = cases.iter().map(|(_, w)| w.rows * w.cols).collect();
    let space = SearchSpace::parse(2.58, None, Some("2,4")).unwrap();
    for &threads in &thread_grid {
        let base = QuantConfig { bits: 2.0, loops: 2, threads, ..QuantConfig::default() };
        let probes: Vec<LayerProbe> = lnames
            .iter()
            .enumerate()
            .map(|(i, name)| LayerProbe {
                name: name.as_str(),
                x: &cases[i].0,
                gram: &grams[i],
                w: &cases[i].1,
                numel: numels[i],
            })
            .collect();
        obs::reset();
        let r = bench(&format!("auto-plan search 4 layers t={threads}"), 1, 3, || {
            black_box(search_plan(&base, &probes, &space).unwrap());
        });
        recs.push(Rec {
            method: "auto-plan",
            bits: "2|4".to_string(),
            threads,
            median_ns: r.median_ns,
            ns_per_channel: r.median_ns as f64 / total_channels as f64,
            chan: chan_summary(),
        });
    }

    // --- fused packed GEMM vs the dense GEMM it replaces (serving path) --
    // Channels arrive as 2/4-bit streams + dequant LUTs; the fused kernel
    // expands through the LUT per channel and never materializes the
    // weight matrix. The dense row times Matrix::matmul over the same
    // shape — the before-this-PR serving cost.
    println!("\n== packed GEMM vs dense GEMM (batch 64, 512x256) ==");
    {
        use beacon_ptq::linalg::{packed_gemm, PackedCol};
        use beacon_ptq::quant::packing::{
            dequant_lut, try_pack_channel, PackedChannel,
        };
        let (gb, gn, gnp) = (64usize, 512usize, 256usize);
        let mut g = Gen { rng: SplitMix64::new(88) };
        let gx = Matrix::from_vec(gb, gn, g.vec_normal(gb * gn, 1.0));
        for &bits in &[BitWidth::B2, BitWidth::B4] {
            let a = alphabet(bits);
            let packed: Vec<PackedChannel> = (0..gnp)
                .map(|_| {
                    let codes: Vec<f64> =
                        (0..gn).map(|_| *g.pick(&a)).collect();
                    try_pack_channel(&codes, 0.1, 0.0, bits).unwrap()
                })
                .collect();
            let luts: Vec<Vec<f32>> =
                packed.iter().map(|p| dequant_lut(p, bits)).collect();
            let cols: Vec<PackedCol> = packed
                .iter()
                .zip(&luts)
                .map(|(p, lut)| PackedCol {
                    bits: p.bits,
                    len: p.len,
                    words: &p.words,
                    lut,
                    group_size: p.group_size as usize,
                    outliers: &p.outliers,
                })
                .collect();
            for &threads in &[1usize, 4] {
                let r = bench(
                    &format!(
                        "packed_gemm {gb}x{gn}x{gnp} {} t={threads}",
                        bits.label()
                    ),
                    1,
                    5,
                    || {
                        black_box(packed_gemm(&cols, &gx, threads));
                    },
                );
                recs.push(Rec {
                    method: "packed-gemm",
                    bits: bits.label(),
                    threads,
                    median_ns: r.median_ns,
                    ns_per_channel: r.median_ns as f64 / gnp as f64,
                    chan: None,
                });
            }
        }
        let wm = Matrix::from_vec(gn, gnp, g.vec_normal(gn * gnp, 0.3));
        let r = bench(&format!("dense matmul {gb}x{gn}x{gnp} t=1"), 1, 5, || {
            black_box(gx.matmul(&wm));
        });
        recs.push(Rec {
            method: "dense-gemm",
            bits: "fp".to_string(),
            threads: 1,
            median_ns: r.median_ns,
            ns_per_channel: r.median_ns as f64 / gnp as f64,
            chan: None,
        });
    }

    // --- serve-path batched forward: the per-batch cost the batching
    // server pays per flush. Chains packed_gemm across all layers of a
    // synthetic packed checkpoint, exactly what serve::worker_loop runs
    // on a full batch. ns/channel normalizes by the total expanded
    // channels across the chain (layers × dim).
    println!("\n== serve-path batched forward (batch 8, 3×256×256) ==");
    {
        use beacon_ptq::serve::{synthetic_store, PackedModel};
        let (sb, sl, sd) = (8usize, 3usize, 256usize);
        for &bits in &[BitWidth::B2, BitWidth::B4] {
            let model =
                PackedModel::from_store(synthetic_store(sl, sd, bits, 0xBA7C))
                    .expect("synthetic store chains by construction");
            let mut g = Gen { rng: SplitMix64::new(90) };
            let sx = Matrix::from_vec(sb, sd, g.vec_normal(sb * sd, 1.0));
            for &threads in &[1usize, 4] {
                let r = bench(
                    &format!(
                        "serve-batch {sb}x{sl}x{sd} {} t={threads}",
                        bits.label()
                    ),
                    1,
                    5,
                    || {
                        black_box(model.forward_batch(&sx, threads));
                    },
                );
                recs.push(Rec {
                    method: "serve-batch",
                    bits: bits.label(),
                    threads,
                    median_ns: r.median_ns,
                    ns_per_channel: r.median_ns as f64 / (sl * sd) as f64,
                    chan: None,
                });
            }
        }
    }

    // --- peak-heap rows: BENCH_memory.json --------------------------------
    // One layer quantize per (method, bits) with the high-water mark
    // re-armed at the section's live level, so each row reports the
    // *transient* peak the kernel adds on top of its inputs.
    println!("\n== peak heap per layer quantize (method × bits, t=1) ==");
    struct MemRec {
        method: &'static str,
        bits: String,
        peak_bytes: u64,
    }
    let mut mem_recs: Vec<MemRec> = Vec::new();
    {
        let mut mem_row =
            |method: &'static str, bits: BitWidth, run: &mut dyn FnMut()| {
                let live0 = memory::live_bytes();
                memory::reset_peak();
                run();
                let peak = memory::peak_bytes().saturating_sub(live0);
                println!("  {method} {}: peak {} bytes", bits.label(), peak);
                mem_recs.push(MemRec { method, bits: bits.label(), peak_bytes: peak });
            };
        for &bits in &[BitWidth::B2, BitWidth::B4] {
            let a = alphabet(bits);
            let opts = BeaconOpts { loops: 4, centering: false, threads: 1 };
            mem_row("beacon", bits, &mut || {
                black_box(beacon_layer_prefactored(&f.l, &f.r, &x, &x, &w, &a, &opts));
            });
        }
        mem_row("rtn", BitWidth::B2, &mut || {
            black_box(rtn_layer(&w, BitWidth::B2));
        });
        mem_row("comq", BitWidth::B2, &mut || {
            black_box(comq_layer(&x, &w, BitWidth::B2, 4));
        });
        mem_row("gptq", BitWidth::B2, &mut || {
            black_box(gptq_layer(&x, &w, BitWidth::B2, 0.01));
        });
    }

    let host = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    let mut s = String::new();
    s.push_str("{\n  \"bench\": \"quant_kernels\",\n");
    s.push_str(&format!(
        "  \"layer\": {{\"rows\": {m}, \"n\": {nn}, \"channels\": {np}}},\n"
    ));
    s.push_str(&format!("  \"host_threads\": {host},\n"));
    s.push_str("  \"results\": [\n");
    for (i, r) in recs.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"method\": \"{}\", \"bits\": \"{}\", \"threads\": {}, \
             \"median_ns\": {}, \"ns_per_channel\": {:.1}",
            r.method, r.bits, r.threads, r.median_ns, r.ns_per_channel,
        ));
        // Optional latency-distribution fields; the perf gate's parser
        // ignores keys it doesn't know, so the baseline grid is unchanged.
        if let Some(c) = r.chan {
            s.push_str(&format!(
                ", \"p50_ns\": {}, \"p95_ns\": {}, \"p99_ns\": {}",
                c.p50, c.p95, c.p99
            ));
        }
        s.push_str(if i + 1 == recs.len() { "}\n" } else { "},\n" });
    }
    s.push_str("  ]\n}\n");
    std::fs::write("BENCH_quant.json", &s).expect("write BENCH_quant.json");
    println!(
        "\nwrote BENCH_quant.json ({} records, host_threads={host})",
        recs.len()
    );

    let mut s = String::new();
    s.push_str("{\n  \"bench\": \"quant_memory\",\n");
    s.push_str(&format!(
        "  \"layer\": {{\"rows\": {m}, \"n\": {nn}, \"channels\": {np}}},\n"
    ));
    s.push_str(&format!("  \"host_threads\": {host},\n"));
    s.push_str("  \"results\": [\n");
    for (i, r) in mem_recs.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"method\": \"{}\", \"bits\": \"{}\", \"threads\": 1, \
             \"peak_bytes\": {}",
            r.method, r.bits, r.peak_bytes,
        ));
        s.push_str(if i + 1 == mem_recs.len() { "}\n" } else { "},\n" });
    }
    s.push_str("  ]\n}\n");
    std::fs::write("BENCH_memory.json", &s).expect("write BENCH_memory.json");
    println!(
        "wrote BENCH_memory.json ({} records, host_threads={host})",
        mem_recs.len()
    );
}
