//! Microbenchmarks of the quantization algorithms (native path): the
//! per-channel Beacon sweep across layer sizes / bit widths / sweep
//! counts, and the per-layer cost of every baseline. These are the
//! numbers behind EXPERIMENTS.md §Perf (L3).

use beacon_ptq::data::rng::SplitMix64;
use beacon_ptq::linalg::{qr_factor, Matrix};
use beacon_ptq::quant::alphabet::{alphabet, BitWidth};
use beacon_ptq::quant::beacon::{beacon_channel, beacon_layer, BeaconOpts};
use beacon_ptq::quant::{comq_layer, gptq_layer, rtn_layer};
use beacon_ptq::util::bench::{bench, black_box};
use beacon_ptq::util::prop::Gen;

fn case(seed: u64, m: usize, n: usize, np: usize) -> (Matrix, Matrix) {
    let mut g = Gen { rng: SplitMix64::new(seed) };
    let x = Matrix::from_vec(m, n, g.vec_normal(m * n, 1.0));
    let w = Matrix::from_vec(n, np, g.vec_normal(n * np, 0.3));
    (x, w)
}

fn main() {
    println!("== quant kernel microbenches (native) ==\n");

    // --- beacon_channel across N (the inner hot path) ---------------------
    for &n in &[64usize, 128, 256] {
        let (x, w) = case(1, 4 * n, n, 1);
        let f = qr_factor(&x, &x);
        let l_cols = f.l.columns();
        let lt_cols = f.r.columns();
        let nnz: Vec<usize> = (0..n).map(|t| t + 1).collect();
        let wcol = w.col(0);
        let a = alphabet(BitWidth::B2);
        bench(&format!("beacon_channel N={n} 2-bit K=4"), 2, 10, || {
            black_box(beacon_channel(&l_cols, &lt_cols, &nnz, &wcol, &a, 4));
        });
    }

    // --- beacon_channel across bit widths ----------------------------------
    let n = 128;
    let (x, w) = case(2, 4 * n, n, 1);
    let f = qr_factor(&x, &x);
    let l_cols = f.l.columns();
    let lt_cols = f.r.columns();
    let nnz: Vec<usize> = (0..n).map(|t| t + 1).collect();
    let wcol = w.col(0);
    for bits in BitWidth::ALL {
        let a = alphabet(bits);
        bench(&format!("beacon_channel N={n} {} K=4", bits.label()), 2, 10, || {
            black_box(beacon_channel(&l_cols, &lt_cols, &nnz, &wcol, &a, 4));
        });
    }

    // --- sweep count scaling ------------------------------------------------
    for &loops in &[0usize, 2, 4, 8] {
        let a = alphabet(BitWidth::B2);
        bench(&format!("beacon_channel N={n} 2-bit K={loops}"), 2, 10, || {
            black_box(beacon_channel(&l_cols, &lt_cols, &nnz, &wcol, &a, loops));
        });
    }

    // --- whole-layer comparison across methods ------------------------------
    println!();
    let (x, w) = case(3, 1088, 64, 192); // tiny-sim qkv shape at full calib
    let a2 = alphabet(BitWidth::B2);
    bench("layer 64x192 beacon (K=4)", 1, 5, || {
        black_box(beacon_layer(&x, &x, &w, &a2, &BeaconOpts::default()));
    });
    bench("layer 64x192 beacon+centering", 1, 5, || {
        black_box(beacon_layer(
            &x, &x, &w, &a2,
            &BeaconOpts { loops: 4, centering: true },
        ));
    });
    bench("layer 64x192 gptq", 1, 5, || {
        black_box(gptq_layer(&x, &w, BitWidth::B2, 0.01));
    });
    bench("layer 64x192 comq (K=4)", 1, 5, || {
        black_box(comq_layer(&x, &w, BitWidth::B2, 4));
    });
    bench("layer 64x192 rtn", 1, 5, || {
        black_box(rtn_layer(&w, BitWidth::B2));
    });
}
