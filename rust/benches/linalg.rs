//! Linear-algebra substrate benches: QR factorization (the §3 memory-
//! efficient reduction) and the gram/matmul kernels under GPTQ/COMQ.

use beacon_ptq::data::rng::SplitMix64;
use beacon_ptq::linalg::{qr_factor, Matrix};
use beacon_ptq::util::bench::{bench, black_box};
use beacon_ptq::util::prop::Gen;

fn random(seed: u64, r: usize, c: usize) -> Matrix {
    let mut g = Gen { rng: SplitMix64::new(seed) };
    Matrix::from_vec(r, c, g.vec_normal(r * c, 1.0))
}

fn main() {
    println!("== linalg benches ==\n");
    for &(m, n) in &[(1088usize, 64usize), (2176, 64), (1088, 128)] {
        let x = random(1, m, n);
        bench(&format!("qr_factor {m}x{n} (no EC)"), 1, 5, || {
            black_box(qr_factor(&x, &x));
        });
        let xt = random(2, m, n);
        bench(&format!("qr_factor {m}x{n} (EC: Qᵀ applied to X too)"), 1, 5, || {
            black_box(qr_factor(&xt, &x));
        });
    }
    println!();
    for &n in &[64usize, 128, 256] {
        let x = random(3, 8 * n, n);
        bench(&format!("gram {}x{n}", 8 * n), 1, 5, || {
            black_box(x.gram());
        });
    }
    let a = random(4, 256, 256);
    let b = random(5, 256, 256);
    bench("matmul 256x256 * 256x256", 1, 5, || {
        black_box(a.matmul(&b));
    });
    let v: Vec<f64> = (0..256).map(|i| i as f64).collect();
    bench("matvec 256x256", 5, 20, || {
        black_box(a.matvec(&v));
    });
}
