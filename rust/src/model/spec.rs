//! ViT architecture description — EXACT mirror of
//! `python/compile/common.py` (`ViTConfig`, `param_spec`,
//! `quantizable_layers`, `ln_param_names`). The flat parameter order is
//! the ABI between this coordinator and the AOT HLO artifacts; a mismatch
//! is caught by `python/tests` + the manifest cross-check in
//! [`crate::runtime::Artifacts`].

#[derive(Debug, Clone, PartialEq)]
pub struct ViTConfig {
    pub name: String,
    pub image: usize,
    pub channels: usize,
    pub patch: usize,
    pub d_model: usize,
    pub depth: usize,
    pub heads: usize,
    pub mlp_ratio: usize,
    pub num_classes: usize,
}

impl ViTConfig {
    pub fn tiny_sim() -> ViTConfig {
        ViTConfig {
            name: "tiny-sim".into(),
            image: 16,
            channels: 3,
            patch: 4,
            d_model: 64,
            depth: 4,
            heads: 4,
            mlp_ratio: 2,
            num_classes: 10,
        }
    }

    pub fn deit_b() -> ViTConfig {
        ViTConfig {
            name: "deit-b".into(),
            image: 224,
            channels: 3,
            patch: 16,
            d_model: 768,
            depth: 12,
            heads: 12,
            mlp_ratio: 4,
            num_classes: 1000,
        }
    }

    pub fn tokens(&self) -> usize {
        (self.image / self.patch) * (self.image / self.patch) + 1
    }

    pub fn patch_dim(&self) -> usize {
        self.patch * self.patch * self.channels
    }

    pub fn d_mlp(&self) -> usize {
        self.d_model * self.mlp_ratio
    }

    pub fn param_count(&self) -> usize {
        param_spec(self).iter().map(|p| p.shape.iter().product::<usize>()).sum()
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

/// Flat (name, shape) list — THE ordering contract with L2.
pub fn param_spec(cfg: &ViTConfig) -> Vec<ParamSpec> {
    let d = cfg.d_model;
    let f = cfg.d_mlp();
    let p = cfg.patch_dim();
    let mut spec = vec![
        ps("patch_embed.w", &[p, d]),
        ps("patch_embed.b", &[d]),
        ps("cls_token", &[1, d]),
        ps("pos_embed", &[cfg.tokens(), d]),
    ];
    for i in 0..cfg.depth {
        let pre = format!("blocks.{i}.");
        spec.push(ps(&format!("{pre}ln1.g"), &[d]));
        spec.push(ps(&format!("{pre}ln1.b"), &[d]));
        spec.push(ps(&format!("{pre}qkv.w"), &[d, 3 * d]));
        spec.push(ps(&format!("{pre}qkv.b"), &[3 * d]));
        spec.push(ps(&format!("{pre}proj.w"), &[d, d]));
        spec.push(ps(&format!("{pre}proj.b"), &[d]));
        spec.push(ps(&format!("{pre}ln2.g"), &[d]));
        spec.push(ps(&format!("{pre}ln2.b"), &[d]));
        spec.push(ps(&format!("{pre}fc1.w"), &[d, f]));
        spec.push(ps(&format!("{pre}fc1.b"), &[f]));
        spec.push(ps(&format!("{pre}fc2.w"), &[f, d]));
        spec.push(ps(&format!("{pre}fc2.b"), &[d]));
    }
    spec.push(ps("ln_f.g", &[d]));
    spec.push(ps("ln_f.b", &[d]));
    spec.push(ps("head.w", &[d, cfg.num_classes]));
    spec.push(ps("head.b", &[cfg.num_classes]));
    spec
}

fn ps(name: &str, shape: &[usize]) -> ParamSpec {
    ParamSpec { name: name.to_string(), shape: shape.to_vec() }
}

/// Weight matrices Beacon quantizes, in pipeline (activation-collection)
/// order. Patch embed + head stay FP by default.
pub fn quantizable_layers(cfg: &ViTConfig) -> Vec<String> {
    let mut out = Vec::new();
    for i in 0..cfg.depth {
        out.push(format!("blocks.{i}.qkv.w"));
        out.push(format!("blocks.{i}.proj.w"));
        out.push(format!("blocks.{i}.fc1.w"));
        out.push(format!("blocks.{i}.fc2.w"));
    }
    out
}

/// LayerNorm parameters tuned by the optional LN pass.
pub fn ln_param_names(cfg: &ViTConfig) -> Vec<String> {
    let mut out = Vec::new();
    for i in 0..cfg.depth {
        out.push(format!("blocks.{i}.ln1.g"));
        out.push(format!("blocks.{i}.ln1.b"));
        out.push(format!("blocks.{i}.ln2.g"));
        out.push(format!("blocks.{i}.ln2.b"));
    }
    out.push("ln_f.g".into());
    out.push("ln_f.b".into());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_count_matches_python() {
        let cfg = ViTConfig::tiny_sim();
        assert_eq!(param_spec(&cfg).len(), 4 + 12 * cfg.depth + 4);
    }

    #[test]
    fn tiny_sim_shapes() {
        let cfg = ViTConfig::tiny_sim();
        let spec = param_spec(&cfg);
        assert_eq!(spec[0].shape, vec![48, 64]); // patch_embed.w
        assert_eq!(spec[3].shape, vec![17, 64]); // pos_embed (16 patches + cls)
        let qkv = spec.iter().find(|p| p.name == "blocks.0.qkv.w").unwrap();
        assert_eq!(qkv.shape, vec![64, 192]);
        let fc1 = spec.iter().find(|p| p.name == "blocks.2.fc1.w").unwrap();
        assert_eq!(fc1.shape, vec![64, 128]);
    }

    #[test]
    fn quantizable_are_matrices_in_spec() {
        let cfg = ViTConfig::tiny_sim();
        let spec = param_spec(&cfg);
        for name in quantizable_layers(&cfg) {
            let p = spec.iter().find(|p| p.name == name).unwrap();
            assert_eq!(p.shape.len(), 2, "{name}");
        }
    }

    #[test]
    fn ln_names_in_spec() {
        let cfg = ViTConfig::tiny_sim();
        let names: Vec<String> =
            param_spec(&cfg).iter().map(|p| p.name.clone()).collect();
        for n in ln_param_names(&cfg) {
            assert!(names.contains(&n), "{n}");
        }
    }

    #[test]
    fn deit_b_param_count() {
        // DeiT-B is ~86M parameters; our mirror must land in that range
        let n = ViTConfig::deit_b().param_count();
        assert!((80_000_000..95_000_000).contains(&n), "{n}");
    }

    #[test]
    fn tokens_and_dims() {
        let cfg = ViTConfig::tiny_sim();
        assert_eq!(cfg.tokens(), 17);
        assert_eq!(cfg.patch_dim(), 48);
        assert_eq!(cfg.d_mlp(), 128);
    }
}
