//! Model substrate: the ViT architecture description mirrored from
//! `python/compile/common.py` (the parameter-ordering ABI with the AOT
//! artifacts) and the WTS1 tensor-bundle store.

pub mod packed_store;
pub mod spec;
pub mod store;

pub use packed_store::{PackedLayer, PackedStore};
pub use spec::{ln_param_names, param_spec, quantizable_layers, ParamSpec, ViTConfig};
pub use store::{TensorBundle, WeightStore};
