//! BPK1/BPK2 packed-checkpoint reader/writer: the on-disk and
//! in-memory format for quantized weights after PR 8 — per-channel bit
//! streams plus dequant metadata, never f32 matrices. See
//! `docs/PACKED_FORMAT.md` for the byte-level layout; the short form:
//!
//! ```text
//! magic "BPK1" | version u32 (=1) | layer_count u32
//! per layer:
//!   name_len u32 | name bytes | rows u32 | cols u32
//!   width_hundredths u32 | channel_count u32 (== cols)
//! per channel:
//!   bits u8 | convention u8 | len u32 | scale f32 | offset f32
//!   nwords u32 (== ceil(len·bits/64)) | words u64[nwords]
//!
//! magic "BPK2" | version u32 (=2) | layer_count u32
//! per layer: (same as BPK1)
//! per channel:
//!   bits u8 | convention u8 | len u32
//!   group_size u32 (0 = one group for the whole channel)
//!   ngroups u32 (== 1 if group_size = 0, else ceil(len/group_size))
//!   (scale f32, offset f32) × ngroups
//!   noutl u32 | (row u32, value f32) × noutl (rows strictly ascending)
//!   nwords u32 (== ceil(len·bits/64)) | words u64[nwords]
//! ```
//!
//! `save` picks the format per store: when every channel is dense
//! (single group, no outlier sidecar) it emits exactly the BPK1 bytes
//! this crate has always written, so pre-scenario checkpoints stay
//! byte-identical and old readers keep working; any grouped or
//! outlier-carrying channel upgrades the whole file to BPK2. `load`
//! reads both.
//!
//! All integers and floats little-endian. `save` → `load` → `save` is
//! byte-identical for both formats: packing zero-initializes the
//! bit-stream words, so even the dead bits of a ragged final word
//! round-trip exactly.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::linalg::{expand_channel_f32, Matrix, PackedCol};
use crate::quant::alphabet::BitWidth;
use crate::quant::packing::{
    dequant_luts, pack_channel_grouped, try_pack_channel, unpack_channel,
    CodeConvention, PackedChannel,
};
use crate::quant::LayerQuant;

pub const PACKED_MAGIC: &[u8; 4] = b"BPK1";
pub const PACKED_VERSION: u32 = 1;
pub const PACKED_MAGIC_V2: &[u8; 4] = b"BPK2";
pub const PACKED_VERSION_V2: u32 = 2;

/// One quantized layer: the weight matrix's columns as packed channels.
/// `rows` is the channel length (W is rows×cols, quantized per column).
#[derive(Debug, Clone)]
pub struct PackedLayer {
    pub name: String,
    pub rows: usize,
    pub width: BitWidth,
    pub channels: Vec<PackedChannel>,
}

impl PackedLayer {
    /// Pack a layer from quantizer output: column-major `codes` (one
    /// inner vec per channel, either convention) with per-channel
    /// scale/offset. `None` when any channel has off-grid codes.
    pub fn pack(
        name: &str,
        codes: &[Vec<f64>],
        scales: &[f64],
        offsets: &[f64],
        width: BitWidth,
    ) -> Option<PackedLayer> {
        assert_eq!(codes.len(), scales.len(), "{name}: scales per channel");
        assert_eq!(codes.len(), offsets.len(), "{name}: offsets per channel");
        let rows = codes.first().map_or(0, Vec::len);
        let channels = codes
            .iter()
            .zip(scales)
            .zip(offsets)
            .map(|((ch, &s), &o)| try_pack_channel(ch, s, o, width))
            .collect::<Option<Vec<_>>>()?;
        Some(PackedLayer {
            name: name.to_string(),
            rows,
            width,
            channels,
        })
    }

    /// Pack a layer straight from a quantizer's [`LayerQuant`],
    /// honoring any grouped/outlier scenario metadata it carries. A
    /// channel whose metadata is dense-representable (no group split,
    /// no sidecar) packs exactly as [`PackedLayer::pack`] would, so a
    /// default-scenario run still produces a pure-BPK1 store.
    pub fn pack_quant(
        name: &str,
        lq: &LayerQuant,
        width: BitWidth,
    ) -> Option<PackedLayer> {
        let Some(meta) = &lq.grouped else {
            return Self::pack(name, &lq.codes, &lq.scales, &lq.offsets, width);
        };
        let rows = lq.codes.first().map_or(0, Vec::len);
        let channels = lq
            .codes
            .iter()
            .enumerate()
            .map(|(j, ch)| {
                if meta.group_size == 0 && meta.outliers[j].is_empty() {
                    try_pack_channel(ch, lq.scales[j], lq.offsets[j], width)
                } else {
                    pack_channel_grouped(
                        ch,
                        &meta.groups[j],
                        meta.group_size,
                        &meta.outliers[j],
                        width,
                    )
                }
            })
            .collect::<Option<Vec<_>>>()?;
        Some(PackedLayer {
            name: name.to_string(),
            rows,
            width,
            channels,
        })
    }

    pub fn cols(&self) -> usize {
        self.channels.len()
    }

    /// Per-channel dequant LUTs — the tables the fused kernel expands
    /// through (one `2^bits` stride per group; a single stride for
    /// dense channels). Build once per layer, reuse across requests.
    pub fn luts(&self) -> Vec<Vec<f32>> {
        self.channels.iter().map(|c| dequant_luts(c, self.width)).collect()
    }

    /// Borrow the channels as fused-kernel views over pre-built LUTs
    /// (from [`PackedLayer::luts`]; must be same length/order).
    pub fn kernel_cols<'a>(&'a self, luts: &'a [Vec<f32>]) -> Vec<PackedCol<'a>> {
        assert_eq!(luts.len(), self.channels.len(), "{}: LUT count", self.name);
        self.channels
            .iter()
            .zip(luts)
            .map(|(c, lut)| PackedCol {
                bits: c.bits,
                len: c.len,
                group_size: c.group_size as usize,
                outliers: &c.outliers,
                words: &c.words,
                lut,
            })
            .collect()
    }

    /// Materialize the dequantized weight matrix (rows×cols). This is
    /// the *reference/fallback* path — serving uses the fused kernel on
    /// [`PackedLayer::kernel_cols`] and never calls this.
    pub fn unpack_matrix(&self) -> Matrix {
        let (rows, cols) = (self.rows, self.cols());
        let mut m = Matrix::zeros(rows, cols);
        for (j, ch) in self.channels.iter().enumerate() {
            let vals = unpack_channel(ch, self.width);
            for (i, v) in vals.iter().enumerate() {
                m[(i, j)] = f64::from(*v);
            }
        }
        m
    }

    /// Dequantize straight to row-major f32 tensor data (the
    /// `WeightStore::set_data` layout) through the fused kernel's
    /// LUT-expansion — one channel of f32 scratch is the only
    /// intermediate, never an f64 matrix. Values are bit-identical to
    /// [`PackedLayer::unpack_matrix`] narrowed to f32 (the LUT entries
    /// *are* `unpack_channel`'s f32 outputs).
    pub fn dequant_f32(&self) -> Vec<f32> {
        let (rows, cols) = (self.rows, self.cols());
        let luts = self.luts();
        let kcols = self.kernel_cols(&luts);
        let mut data = vec![0.0f32; rows * cols];
        let mut scratch = vec![0.0f32; rows];
        for (j, col) in kcols.iter().enumerate() {
            expand_channel_f32(col, &mut scratch);
            for (i, v) in scratch.iter().enumerate() {
                data[i * cols + j] = *v;
            }
        }
        data
    }

    /// Heap footprint (bit-stream words + per-channel struct + name),
    /// for the resident-bytes registry.
    pub fn resident_bytes(&self) -> u64 {
        let chans: usize =
            self.channels.iter().map(PackedChannel::resident_bytes).sum();
        (chans + self.name.len()) as u64
    }
}

/// Ordered set of packed layers: the quantized checkpoint as shipped.
#[derive(Debug, Clone, Default)]
pub struct PackedStore {
    pub layers: Vec<PackedLayer>,
}

impl PackedStore {
    pub fn get(&self, name: &str) -> Option<&PackedLayer> {
        self.layers.iter().find(|l| l.name == name)
    }

    /// Summed heap footprint of all layers — compare against
    /// `WeightStore::resident_bytes` for the storage-ratio assertion.
    pub fn resident_bytes(&self) -> u64 {
        self.layers.iter().map(PackedLayer::resident_bytes).sum()
    }

    /// Write the store, picking the narrowest format that can carry
    /// it: pure-dense stores emit exactly the historical BPK1 bytes,
    /// anything with group splits or outlier sidecars emits BPK2.
    pub fn save(&self, path: &Path) -> Result<()> {
        let all_dense = self
            .layers
            .iter()
            .all(|l| l.channels.iter().all(PackedChannel::is_dense));
        if all_dense {
            self.save_v1(path)
        } else {
            self.save_v2(path)
        }
    }

    fn save_v1(&self, path: &Path) -> Result<()> {
        let mut w = BufWriter::new(
            File::create(path).with_context(|| format!("create {path:?}"))?,
        );
        w.write_all(PACKED_MAGIC)?;
        w.write_all(&PACKED_VERSION.to_le_bytes())?;
        w.write_all(&(self.layers.len() as u32).to_le_bytes())?;
        for l in &self.layers {
            w.write_all(&(l.name.len() as u32).to_le_bytes())?;
            w.write_all(l.name.as_bytes())?;
            w.write_all(&(l.rows as u32).to_le_bytes())?;
            w.write_all(&(l.cols() as u32).to_le_bytes())?;
            w.write_all(&width_hundredths(l.width).to_le_bytes())?;
            w.write_all(&(l.channels.len() as u32).to_le_bytes())?;
            for c in &l.channels {
                w.write_all(&[c.bits as u8, convention_byte(c.convention)])?;
                w.write_all(&(c.len as u32).to_le_bytes())?;
                w.write_all(&c.scale.to_le_bytes())?;
                w.write_all(&c.offset.to_le_bytes())?;
                w.write_all(&(c.words.len() as u32).to_le_bytes())?;
                for word in &c.words {
                    w.write_all(&word.to_le_bytes())?;
                }
            }
        }
        w.flush()?;
        if let Ok(md) = std::fs::metadata(path) {
            crate::obs::counter("io.write_bytes", md.len());
        }
        Ok(())
    }

    fn save_v2(&self, path: &Path) -> Result<()> {
        let mut w = BufWriter::new(
            File::create(path).with_context(|| format!("create {path:?}"))?,
        );
        w.write_all(PACKED_MAGIC_V2)?;
        w.write_all(&PACKED_VERSION_V2.to_le_bytes())?;
        w.write_all(&(self.layers.len() as u32).to_le_bytes())?;
        for l in &self.layers {
            w.write_all(&(l.name.len() as u32).to_le_bytes())?;
            w.write_all(l.name.as_bytes())?;
            w.write_all(&(l.rows as u32).to_le_bytes())?;
            w.write_all(&(l.cols() as u32).to_le_bytes())?;
            w.write_all(&width_hundredths(l.width).to_le_bytes())?;
            w.write_all(&(l.channels.len() as u32).to_le_bytes())?;
            for c in &l.channels {
                w.write_all(&[c.bits as u8, convention_byte(c.convention)])?;
                w.write_all(&(c.len as u32).to_le_bytes())?;
                w.write_all(&c.group_size.to_le_bytes())?;
                let groups = c.effective_groups();
                w.write_all(&(groups.len() as u32).to_le_bytes())?;
                for (s, o) in &groups {
                    w.write_all(&s.to_le_bytes())?;
                    w.write_all(&o.to_le_bytes())?;
                }
                w.write_all(&(c.outliers.len() as u32).to_le_bytes())?;
                for (row, val) in &c.outliers {
                    w.write_all(&row.to_le_bytes())?;
                    w.write_all(&val.to_le_bytes())?;
                }
                w.write_all(&(c.words.len() as u32).to_le_bytes())?;
                for word in &c.words {
                    w.write_all(&word.to_le_bytes())?;
                }
            }
        }
        w.flush()?;
        if let Ok(md) = std::fs::metadata(path) {
            crate::obs::counter("io.write_bytes", md.len());
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<PackedStore> {
        let mut r = BufReader::new(
            File::open(path).with_context(|| format!("open {path:?}"))?,
        );
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)
            .with_context(|| format!("truncated packed-store header in {path:?}"))?;
        let v2 = &magic == PACKED_MAGIC_V2;
        if !v2 && &magic != PACKED_MAGIC {
            bail!(
                "bad packed-store magic in {path:?}: {magic:02x?} \
                 (want BPK1 or BPK2)"
            );
        }
        let version = read_u32(&mut r, path, "version")?;
        if v2 && version != PACKED_VERSION_V2 {
            bail!(
                "unsupported BPK2 version {version} in {path:?} \
                 (this build reads version {PACKED_VERSION_V2})"
            );
        }
        if !v2 && version > PACKED_VERSION {
            bail!(
                "unsupported BPK1 version {version} in {path:?} \
                 (this build reads up to {PACKED_VERSION})"
            );
        }
        let nlayers = read_u32(&mut r, path, "layer count")? as usize;
        let mut layers = Vec::with_capacity(nlayers);
        for li in 0..nlayers {
            let name_len = read_u32(&mut r, path, "name length")? as usize;
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name).with_context(|| {
                format!("truncated layer {li} name in {path:?}")
            })?;
            let name = String::from_utf8(name)
                .with_context(|| format!("layer {li} name not UTF-8"))?;
            let rows = read_u32(&mut r, path, "rows")? as usize;
            let cols = read_u32(&mut r, path, "cols")? as usize;
            let hundredths = read_u32(&mut r, path, "bit width")?;
            let width = width_from_hundredths(hundredths).ok_or_else(|| {
                anyhow::anyhow!(
                    "layer '{name}': unknown bit width {}.{:02} in {path:?}",
                    hundredths / 100,
                    hundredths % 100
                )
            })?;
            let nchan = read_u32(&mut r, path, "channel count")? as usize;
            if nchan != cols {
                bail!(
                    "layer '{name}': channel count {nchan} != cols {cols} \
                     in {path:?}"
                );
            }
            let mut channels = Vec::with_capacity(nchan);
            for ci in 0..nchan {
                let mut head = [0u8; 2];
                r.read_exact(&mut head).with_context(|| {
                    format!("truncated channel {ci} of '{name}' in {path:?}")
                })?;
                let bits = u32::from(head[0]);
                if bits == 0 || bits > 16 {
                    bail!(
                        "layer '{name}' channel {ci}: bad bit count {bits} \
                         in {path:?}"
                    );
                }
                let convention = convention_from_byte(head[1]).ok_or_else(
                    || {
                        anyhow::anyhow!(
                            "layer '{name}' channel {ci}: bad convention \
                             byte {} in {path:?}",
                            head[1]
                        )
                    },
                )?;
                let len = read_u32(&mut r, path, "channel length")? as usize;
                let (scale, offset, group_size, groups, outliers) = if v2 {
                    let gs = read_u32(&mut r, path, "group size")? as usize;
                    if gs == 1 {
                        bail!(
                            "layer '{name}' channel {ci}: bad group size 1 \
                             in {path:?}"
                        );
                    }
                    let ngroups = read_u32(&mut r, path, "group count")? as usize;
                    let expect = if gs == 0 || len == 0 {
                        1
                    } else {
                        (len + gs - 1) / gs
                    };
                    if ngroups != expect {
                        bail!(
                            "layer '{name}' channel {ci}: bad group count \
                             {ngroups} for length {len} at group size {gs} \
                             (want {expect}) in {path:?}"
                        );
                    }
                    let mut pairs = Vec::with_capacity(ngroups);
                    for _ in 0..ngroups {
                        let s = read_f32(&mut r, path, "group scale")?;
                        let o = read_f32(&mut r, path, "group offset")?;
                        pairs.push((s, o));
                    }
                    let noutl = read_u32(&mut r, path, "outlier count")? as usize;
                    if noutl > len {
                        bail!(
                            "layer '{name}' channel {ci}: bad outlier count \
                             {noutl} for length {len} in {path:?}"
                        );
                    }
                    let mut outl = Vec::with_capacity(noutl);
                    let mut prev: i64 = -1;
                    for _ in 0..noutl {
                        let row = read_u32(&mut r, path, "outlier sidecar row")?;
                        let val = read_f32(&mut r, path, "outlier sidecar value")?;
                        if row as usize >= len || i64::from(row) <= prev {
                            bail!(
                                "layer '{name}' channel {ci}: bad outlier row \
                                 {row} (rows must be strictly ascending and \
                                 < {len}) in {path:?}"
                            );
                        }
                        prev = i64::from(row);
                        outl.push((row, val));
                    }
                    let (s0, o0) = pairs[0];
                    // a single whole-channel group is carried on the
                    // channel's own scale/offset, like BPK1
                    let groups = if gs == 0 { Vec::new() } else { pairs };
                    (s0, o0, gs as u32, groups, outl)
                } else {
                    let mut f = [0u8; 4];
                    r.read_exact(&mut f).with_context(|| {
                        format!("truncated scale of '{name}' in {path:?}")
                    })?;
                    let scale = f32::from_le_bytes(f);
                    r.read_exact(&mut f).with_context(|| {
                        format!("truncated offset of '{name}' in {path:?}")
                    })?;
                    let offset = f32::from_le_bytes(f);
                    (scale, offset, 0u32, Vec::new(), Vec::new())
                };
                let nwords = read_u32(&mut r, path, "word count")? as usize;
                let expect = (len * bits as usize + 63) / 64;
                if nwords != expect {
                    bail!(
                        "layer '{name}' channel {ci}: {nwords} words for \
                         {len}×{bits}-bit stream (want {expect}) in {path:?}"
                    );
                }
                let mut words = vec![0u64; nwords];
                for (wi, word) in words.iter_mut().enumerate() {
                    let mut b = [0u8; 8];
                    r.read_exact(&mut b).with_context(|| {
                        format!(
                            "truncated payload at word {wi} of '{name}' \
                             channel {ci} in {path:?}"
                        )
                    })?;
                    *word = u64::from_le_bytes(b);
                }
                if len != rows {
                    bail!(
                        "layer '{name}' channel {ci}: length {len} != rows \
                         {rows} in {path:?}"
                    );
                }
                channels.push(PackedChannel {
                    bits,
                    len,
                    scale,
                    offset,
                    convention,
                    group_size,
                    groups,
                    outliers,
                    words,
                });
            }
            layers.push(PackedLayer { name, rows, width, channels });
        }
        if let Ok(md) = std::fs::metadata(path) {
            crate::obs::counter("io.read_bytes", md.len());
        }
        Ok(PackedStore { layers })
    }
}

fn width_hundredths(w: BitWidth) -> u32 {
    (w.0 * 100.0).round() as u32
}

fn width_from_hundredths(h: u32) -> Option<BitWidth> {
    BitWidth::parse(&format!("{}.{:02}", h / 100, h % 100))
}

fn convention_byte(c: CodeConvention) -> u8 {
    match c {
        CodeConvention::Alphabet => 0,
        CodeConvention::Levels => 1,
    }
}

fn convention_from_byte(b: u8) -> Option<CodeConvention> {
    match b {
        0 => Some(CodeConvention::Alphabet),
        1 => Some(CodeConvention::Levels),
        _ => None,
    }
}

fn read_u32<R: Read>(r: &mut R, path: &Path, what: &str) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)
        .with_context(|| format!("truncated {what} in {path:?}"))?;
    Ok(u32::from_le_bytes(b))
}

fn read_f32<R: Read>(r: &mut R, path: &Path, what: &str) -> Result<f32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)
        .with_context(|| format!("truncated {what} in {path:?}"))?;
    Ok(f32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::alphabet::alphabet;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("beacon_ptq_packed_store_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sample_store() -> PackedStore {
        let mut layers = Vec::new();
        for (li, (width, rows, cols)) in [
            (BitWidth::B2, 70usize, 3usize), // ragged tail
            (BitWidth::B3, 64, 2),           // word straddles
            (BitWidth::B4, 32, 4),           // exact word fill
        ]
        .into_iter()
        .enumerate()
        {
            let alph = alphabet(width);
            let codes: Vec<Vec<f64>> = (0..cols)
                .map(|j| {
                    (0..rows)
                        .map(|i| alph[(i * 5 + j) % alph.len()])
                        .collect()
                })
                .collect();
            let scales: Vec<f64> = (0..cols).map(|j| 0.1 + j as f64 * 0.05).collect();
            let offsets: Vec<f64> = (0..cols).map(|j| j as f64 * 0.01).collect();
            let layer = PackedLayer::pack(
                &format!("layer.{li}"),
                &codes,
                &scales,
                &offsets,
                width,
            )
            .unwrap();
            layers.push(layer);
        }
        // one integer-level channel layer (min-max convention)
        let codes: Vec<Vec<f64>> =
            vec![(0..48).map(|i| f64::from(i % 8)).collect()];
        layers.push(
            PackedLayer::pack("layer.lv", &codes, &[0.5], &[0.25], BitWidth::B3)
                .unwrap(),
        );
        PackedStore { layers }
    }

    fn grouped_store() -> PackedStore {
        // integer-level codes, g16 over 40 rows (ragged 8-row tail),
        // one channel with an outlier sidecar and one without
        let width = BitWidth::B3;
        let mk = |seed: usize, outl: &[(usize, f64)]| {
            let codes: Vec<f64> =
                (0..40).map(|i| ((i * 5 + seed) % 8) as f64).collect();
            let groups = [(0.5, 0.125), (0.25, -0.25), (1.0, 0.0)];
            pack_channel_grouped(&codes, &groups, 16, outl, width).unwrap()
        };
        PackedStore {
            layers: vec![PackedLayer {
                name: "g.layer".into(),
                rows: 40,
                width,
                channels: vec![mk(1, &[(5, 9.0)]), mk(3, &[])],
            }],
        }
    }

    /// Byte offset of channel 0's record in a single-layer BPK2 file.
    fn bpk2_channel0_offset(bytes: &[u8]) -> usize {
        let name_len =
            u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
        // header(12) + name_len(4) + name + rows + cols + width + nchan
        12 + 4 + name_len + 4 + 4 + 4 + 4
    }

    #[test]
    fn dense_store_still_saves_as_bpk1() {
        let store = sample_store();
        let p = tmp("dense_v1.bpk");
        store.save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(&bytes[0..4], PACKED_MAGIC);
    }

    #[test]
    fn grouped_store_saves_as_bpk2_and_round_trips() {
        let store = grouped_store();
        let p1 = tmp("g_rt1.bpk");
        let p2 = tmp("g_rt2.bpk");
        store.save(&p1).unwrap();
        let bytes = std::fs::read(&p1).unwrap();
        assert_eq!(&bytes[0..4], PACKED_MAGIC_V2);
        let back = PackedStore::load(&p1).unwrap();
        back.save(&p2).unwrap();
        assert_eq!(bytes, std::fs::read(&p2).unwrap(), "save→load→save");
        let (a, b) = (&store.layers[0], &back.layers[0]);
        for (ca, cb) in a.channels.iter().zip(&b.channels) {
            assert_eq!(ca.group_size, cb.group_size);
            assert_eq!(ca.groups.len(), cb.groups.len());
            for (ga, gb) in ca.groups.iter().zip(&cb.groups) {
                assert_eq!(ga.0.to_bits(), gb.0.to_bits());
                assert_eq!(ga.1.to_bits(), gb.1.to_bits());
            }
            assert_eq!(ca.outliers, cb.outliers);
            assert_eq!(ca.words, cb.words);
            let va = unpack_channel(ca, a.width);
            let vb = unpack_channel(cb, b.width);
            for (x, y) in va.iter().zip(&vb) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn bpk2_future_version_is_structured_error() {
        let store = grouped_store();
        let p = tmp("g_future.bpk");
        store.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[4..8].copy_from_slice(&9u32.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let err = PackedStore::load(&p).unwrap_err();
        assert!(
            format!("{err:#}").contains("unsupported BPK2 version 9"),
            "{err:#}"
        );
    }

    #[test]
    fn bpk2_bad_group_count_is_structured_error() {
        let store = grouped_store();
        let p = tmp("g_badcount.bpk");
        store.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        // channel record: bits(1) + convention(1) + len(4) + group_size(4)
        let ngroups_off = bpk2_channel0_offset(&bytes) + 1 + 1 + 4 + 4;
        bytes[ngroups_off..ngroups_off + 4]
            .copy_from_slice(&7u32.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let err = PackedStore::load(&p).unwrap_err();
        assert!(format!("{err:#}").contains("bad group count 7"), "{err:#}");
    }

    #[test]
    fn bpk2_truncated_sidecar_is_structured_error() {
        let store = grouped_store();
        let p = tmp("g_trunc.bpk");
        store.save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        // cut mid-way through channel 0's first outlier record:
        // 3 groups × 8 bytes follow (ngroups at +10), then noutl(4)
        let row_off = bpk2_channel0_offset(&bytes) + 1 + 1 + 4 + 4 + 4 + 24 + 4;
        std::fs::write(&p, &bytes[..row_off + 2]).unwrap();
        let err = PackedStore::load(&p).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
    }

    #[test]
    fn bpk2_bad_outlier_row_is_structured_error() {
        let store = grouped_store();
        let p = tmp("g_badrow.bpk");
        store.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let row_off = bpk2_channel0_offset(&bytes) + 1 + 1 + 4 + 4 + 4 + 24 + 4;
        bytes[row_off..row_off + 4].copy_from_slice(&40u32.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let err = PackedStore::load(&p).unwrap_err();
        assert!(format!("{err:#}").contains("bad outlier row 40"), "{err:#}");
    }

    #[test]
    fn save_load_save_byte_identical() {
        let store = sample_store();
        let p1 = tmp("rt1.bpk");
        let p2 = tmp("rt2.bpk");
        store.save(&p1).unwrap();
        let back = PackedStore::load(&p1).unwrap();
        back.save(&p2).unwrap();
        let b1 = std::fs::read(&p1).unwrap();
        let b2 = std::fs::read(&p2).unwrap();
        assert_eq!(b1, b2, "save→load→save must be byte-identical");
    }

    #[test]
    fn roundtrip_preserves_channels_bit_identically() {
        let store = sample_store();
        let p = tmp("rt3.bpk");
        store.save(&p).unwrap();
        let back = PackedStore::load(&p).unwrap();
        assert_eq!(back.layers.len(), store.layers.len());
        for (a, b) in store.layers.iter().zip(&back.layers) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.rows, b.rows);
            assert_eq!(width_hundredths(a.width), width_hundredths(b.width));
            for (ca, cb) in a.channels.iter().zip(&b.channels) {
                assert_eq!(ca.bits, cb.bits);
                assert_eq!(ca.len, cb.len);
                assert_eq!(ca.convention, cb.convention);
                assert_eq!(ca.scale.to_bits(), cb.scale.to_bits());
                assert_eq!(ca.offset.to_bits(), cb.offset.to_bits());
                assert_eq!(ca.words, cb.words);
                // dequantized values are bit-identical too
                let va = unpack_channel(ca, a.width);
                let vb = unpack_channel(cb, b.width);
                for (x, y) in va.iter().zip(&vb) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
        }
    }

    #[test]
    fn corrupt_magic_is_structured_error() {
        let store = sample_store();
        let p = tmp("bad_magic.bpk");
        store.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[0] = b'X';
        std::fs::write(&p, &bytes).unwrap();
        let err = PackedStore::load(&p).unwrap_err();
        assert!(format!("{err:#}").contains("magic"), "{err:#}");
    }

    #[test]
    fn future_version_is_structured_error() {
        let store = sample_store();
        let p = tmp("future.bpk");
        store.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[4..8].copy_from_slice(&99u32.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let err = PackedStore::load(&p).unwrap_err();
        assert!(
            format!("{err:#}").contains("unsupported BPK1 version 99"),
            "{err:#}"
        );
    }

    #[test]
    fn truncated_payload_is_structured_error() {
        let store = sample_store();
        let p = tmp("trunc.bpk");
        store.save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        // chop at several depths: inside header, inside a layer table,
        // inside a channel's words
        for cut in [2, 9, 40, bytes.len() - 3] {
            std::fs::write(&p, &bytes[..cut]).unwrap();
            let err = PackedStore::load(&p).unwrap_err();
            assert!(
                format!("{err:#}").contains("truncated"),
                "cut {cut}: {err:#}"
            );
        }
    }

    #[test]
    fn channel_count_mismatch_is_structured_error() {
        let store = sample_store();
        let p = tmp("chmm.bpk");
        store.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        // first layer record starts at offset 12; its fields:
        // name_len(4) + name(7:"layer.0") + rows(4) + cols(4) +
        // width(4) → channel_count at 12+4+7+4+4+4 = 35
        let name_len =
            u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
        let chan_off = 12 + 4 + name_len + 4 + 4 + 4;
        bytes[chan_off..chan_off + 4].copy_from_slice(&7u32.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let err = PackedStore::load(&p).unwrap_err();
        assert!(
            format!("{err:#}").contains("channel count 7"),
            "{err:#}"
        );
    }

    #[test]
    fn unpack_matrix_matches_channels() {
        let store = sample_store();
        let l = &store.layers[0];
        let m = l.unpack_matrix();
        assert_eq!((m.rows, m.cols), (l.rows, l.cols()));
        for (j, ch) in l.channels.iter().enumerate() {
            let vals = unpack_channel(ch, l.width);
            for (i, v) in vals.iter().enumerate() {
                assert_eq!(m[(i, j)], f64::from(*v));
            }
        }
    }

    #[test]
    fn dequant_f32_matches_unpack_matrix_bitwise() {
        let store = sample_store();
        for l in &store.layers {
            let data = l.dequant_f32();
            assert_eq!(data.len(), l.rows * l.cols());
            let m = l.unpack_matrix();
            for i in 0..l.rows {
                for j in 0..l.cols() {
                    assert_eq!(
                        data[i * l.cols() + j].to_bits(),
                        (m[(i, j)] as f32).to_bits(),
                        "{} ({i},{j})",
                        l.name
                    );
                }
            }
        }
    }

    #[test]
    fn kernel_cols_expose_streams_and_luts() {
        let store = sample_store();
        let l = &store.layers[1];
        let luts = l.luts();
        let cols = l.kernel_cols(&luts);
        assert_eq!(cols.len(), l.cols());
        for (pc, ch) in cols.iter().zip(&l.channels) {
            assert_eq!(pc.bits, ch.bits);
            assert_eq!(pc.len, ch.len);
            assert_eq!(pc.lut.len(), 1 << ch.bits);
        }
    }

    #[test]
    fn packed_resident_beats_f32() {
        let store = sample_store();
        for l in &store.layers {
            let f32_bytes = (l.rows * l.cols() * 4) as u64;
            assert!(
                l.resident_bytes() < f32_bytes,
                "{}: {} vs {}",
                l.name,
                l.resident_bytes(),
                f32_bytes
            );
        }
    }

    #[test]
    fn pack_rejects_off_grid_layers() {
        let codes = vec![vec![0.25f64; 8]];
        assert!(PackedLayer::pack("x", &codes, &[1.0], &[0.0], BitWidth::B2)
            .is_none());
    }
}
