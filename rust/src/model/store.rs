//! WTS1 tensor-bundle reader/writer (mirror of `python/compile/io.py`) and
//! the mutable [`WeightStore`] the pipeline quantizes in place.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::spec::{param_spec, ViTConfig};
use crate::linalg::Matrix;

#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// View a rank-2 tensor as an f64 Matrix.
    pub fn to_matrix(&self) -> Matrix {
        assert_eq!(self.shape.len(), 2, "{} is not rank-2", self.name);
        Matrix::from_f32(self.shape[0], self.shape[1], &self.data)
    }

    pub fn from_matrix(name: &str, m: &Matrix) -> Tensor {
        Tensor {
            name: name.to_string(),
            shape: vec![m.rows, m.cols],
            data: m.to_f32(),
        }
    }

    /// Heap footprint of this tensor (payload + name + shape), for the
    /// resident-bytes registry.
    pub fn resident_bytes(&self) -> u64 {
        (self.data.len() * 4 + self.name.len() + self.shape.len() * 8) as u64
    }
}

#[derive(Debug, Clone, Default)]
pub struct TensorBundle {
    pub tensors: Vec<Tensor>,
}

impl TensorBundle {
    pub fn load(path: &Path) -> Result<TensorBundle> {
        let mut r = BufReader::new(
            File::open(path).with_context(|| format!("open {path:?}"))?,
        );
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != b"WTS1" {
            bail!("bad WTS1 magic in {path:?}");
        }
        let n = read_u32(&mut r)? as usize;
        let mut tensors = Vec::with_capacity(n);
        for _ in 0..n {
            let name_len = read_u32(&mut r)? as usize;
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let ndim = read_u32(&mut r)? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u32(&mut r)? as usize);
            }
            let numel: usize = shape.iter().product::<usize>().max(1);
            let mut buf = vec![0u8; numel * 4];
            r.read_exact(&mut buf)?;
            let data: Vec<f32> = buf
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                .collect();
            tensors.push(Tensor {
                name: String::from_utf8(name)?,
                shape,
                data,
            });
        }
        if let Ok(md) = std::fs::metadata(path) {
            crate::obs::counter("io.read_bytes", md.len());
        }
        Ok(TensorBundle { tensors })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut w = BufWriter::new(File::create(path)?);
        w.write_all(b"WTS1")?;
        w.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for t in &self.tensors {
            w.write_all(&(t.name.len() as u32).to_le_bytes())?;
            w.write_all(t.name.as_bytes())?;
            w.write_all(&(t.shape.len() as u32).to_le_bytes())?;
            for d in &t.shape {
                w.write_all(&(*d as u32).to_le_bytes())?;
            }
            for v in &t.data {
                w.write_all(&v.to_le_bytes())?;
            }
        }
        w.flush()?;
        if let Ok(md) = std::fs::metadata(path) {
            crate::obs::counter("io.write_bytes", md.len());
        }
        Ok(())
    }
}

/// Named, ordered parameter set for one model; quantization mutates it in
/// place and the runtime feeds it to executables in spec order.
#[derive(Debug, Clone)]
pub struct WeightStore {
    pub cfg: ViTConfig,
    order: Vec<String>,
    tensors: BTreeMap<String, Tensor>,
}

impl WeightStore {
    /// Load and validate against the config's parameter spec.
    pub fn load(path: &Path, cfg: &ViTConfig) -> Result<WeightStore> {
        let bundle = TensorBundle::load(path)?;
        let spec = param_spec(cfg);
        if bundle.tensors.len() != spec.len() {
            bail!(
                "weight bundle has {} tensors, spec wants {}",
                bundle.tensors.len(),
                spec.len()
            );
        }
        let mut tensors = BTreeMap::new();
        let mut order = Vec::with_capacity(spec.len());
        for (t, s) in bundle.tensors.into_iter().zip(&spec) {
            if t.name != s.name {
                bail!("param order mismatch: got '{}', want '{}'", t.name, s.name);
            }
            if t.shape != s.shape {
                bail!(
                    "param '{}' shape {:?} != spec {:?}",
                    t.name,
                    t.shape,
                    s.shape
                );
            }
            order.push(t.name.clone());
            tensors.insert(t.name.clone(), t);
        }
        Ok(WeightStore { cfg: cfg.clone(), order, tensors })
    }

    pub fn get(&self, name: &str) -> &Tensor {
        self.tensors
            .get(name)
            .unwrap_or_else(|| panic!("unknown param '{name}'"))
    }

    pub fn matrix(&self, name: &str) -> Matrix {
        self.get(name).to_matrix()
    }

    pub fn set_matrix(&mut self, name: &str, m: &Matrix) {
        let t = self
            .tensors
            .get_mut(name)
            .unwrap_or_else(|| panic!("unknown param '{name}'"));
        assert_eq!(t.shape, vec![m.rows, m.cols], "{name} shape mismatch");
        t.data = m.to_f32();
    }

    pub fn set_data(&mut self, name: &str, data: Vec<f32>) {
        let t = self
            .tensors
            .get_mut(name)
            .unwrap_or_else(|| panic!("unknown param '{name}'"));
        assert_eq!(t.numel(), data.len(), "{name} numel mismatch");
        t.data = data;
    }

    /// Tensors in spec order (the executable input order).
    pub fn ordered(&self) -> Vec<&Tensor> {
        self.order.iter().map(|n| &self.tensors[n]).collect()
    }

    pub fn names(&self) -> &[String] {
        &self.order
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let bundle = TensorBundle {
            tensors: self.ordered().into_iter().cloned().collect(),
        };
        bundle.save(path)
    }

    /// Summed heap footprint of all tensors, for the resident-bytes
    /// registry (f32 payloads dominate; map/order overhead is noise).
    pub fn resident_bytes(&self) -> u64 {
        self.tensors.values().map(Tensor::resident_bytes).sum()
    }
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("beacon_ptq_store_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn dummy_store(cfg: &ViTConfig) -> WeightStore {
        let spec = param_spec(cfg);
        let tensors: Vec<Tensor> = spec
            .iter()
            .enumerate()
            .map(|(i, s)| Tensor {
                name: s.name.clone(),
                shape: s.shape.clone(),
                data: vec![i as f32 * 0.01; s.shape.iter().product()],
            })
            .collect();
        let p = tmp("dummy.bin");
        TensorBundle { tensors }.save(&p).unwrap();
        WeightStore::load(&p, cfg).unwrap()
    }

    #[test]
    fn bundle_roundtrip() {
        let b = TensorBundle {
            tensors: vec![
                Tensor { name: "a".into(), shape: vec![2, 3], data: vec![1.0; 6] },
                Tensor { name: "b".into(), shape: vec![4], data: vec![2.0; 4] },
            ],
        };
        let p = tmp("rt.bin");
        b.save(&p).unwrap();
        let back = TensorBundle::load(&p).unwrap();
        assert_eq!(back.tensors.len(), 2);
        assert_eq!(back.tensors[0].shape, vec![2, 3]);
        assert_eq!(back.tensors[1].data, vec![2.0; 4]);
    }

    #[test]
    fn store_validates_and_orders() {
        let cfg = ViTConfig::tiny_sim();
        let store = dummy_store(&cfg);
        let ordered = store.ordered();
        let spec = param_spec(&cfg);
        for (t, s) in ordered.iter().zip(&spec) {
            assert_eq!(t.name, s.name);
        }
    }

    #[test]
    fn resident_bytes_dominated_by_payload() {
        let cfg = ViTConfig::tiny_sim();
        let store = dummy_store(&cfg);
        let payload: u64 = store
            .ordered()
            .iter()
            .map(|t| (t.data.len() * 4) as u64)
            .sum();
        let total = store.resident_bytes();
        assert!(total >= payload);
        // name/shape overhead is small next to the f32 payloads
        assert!(total < payload + payload / 4 + 4096, "{total} vs {payload}");
    }

    #[test]
    fn store_mutation() {
        let cfg = ViTConfig::tiny_sim();
        let mut store = dummy_store(&cfg);
        let m = Matrix::zeros(64, 192);
        store.set_matrix("blocks.0.qkv.w", &m);
        assert!(store.get("blocks.0.qkv.w").data.iter().all(|v| *v == 0.0));
    }

    #[test]
    fn store_rejects_wrong_order() {
        let cfg = ViTConfig::tiny_sim();
        let spec = param_spec(&cfg);
        let mut tensors: Vec<Tensor> = spec
            .iter()
            .map(|s| Tensor {
                name: s.name.clone(),
                shape: s.shape.clone(),
                data: vec![0.0; s.shape.iter().product()],
            })
            .collect();
        tensors.swap(0, 1);
        let p = tmp("bad_order.bin");
        TensorBundle { tensors }.save(&p).unwrap();
        assert!(WeightStore::load(&p, &cfg).is_err());
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn set_matrix_checks_shape() {
        let cfg = ViTConfig::tiny_sim();
        let mut store = dummy_store(&cfg);
        store.set_matrix("blocks.0.qkv.w", &Matrix::zeros(2, 2));
    }
}
