//! `beacon` — the leader CLI for the Beacon PTQ stack.
//!
//! Subcommands:
//!   info                       artifact + model summary
//!   quantize [flags]           run one PTQ configuration, report top-1
//!   plan                       loss-aware plan search only (emit manifest)
//!   budget-sweep               searched vs uniform plans across budgets
//!   eval                       evaluate the FP model
//!   table1 / table2            regenerate the paper's tables
//!   convergence                F1: objective vs sweep count
//!   ablate-calib / ablate-ec   ablations A1 / A2
//!   runtime-row                Table 1 runtime row (× GPTQ)
//!
//! Common flags: --artifacts DIR (default `artifacts`), --model NAME
//! (default `tiny-sim`), --backend pjrt|native, --config FILE, plus any
//! QuantConfig key (--bits 2 --loops 4 --ec --centering --ln_tune
//! --threads 4 ...). `--threads N` sets the layer/channel scheduler
//! budget (0 = auto via BEACON_THREADS / core count); results are
//! bit-identical at any thread count.
//!
//! Mixed plans: `--override 'pattern=spec'` (repeatable; also accepts a
//! `;`-separated list) layers glob overrides over the base config, e.g.
//! `--override 'blocks.*.fc?.w=comq:4' --override 'blocks.3.*=:3'`.
//! `--config FILE` accepts `[layer "pattern"]` sections in the same
//! spec language, and `--save-plan FILE` writes the fully resolved
//! per-layer manifest for exact reproduction.
//!
//! Searched plans: `quantize --auto-plan --budget-bits B` (or the `plan`
//! subcommand for search-only) probes every candidate `(method, bits)`
//! per layer against the calibration grams and greedily allocates widths
//! under the size-weighted effective-bits budget; `--plan-methods` /
//! `--plan-bits` (comma lists) narrow the candidate grid and
//! `--plan-groups` / `--plan-outliers` add the grouped/outlier scenario
//! axes. The searched plan is an ordinary manifest: `--save-plan` makes
//! it reproducible.

use std::path::PathBuf;

use anyhow::{bail, Result};

use beacon_ptq::config::{PlanBuilder, QuantConfig, SearchSpace};
use beacon_ptq::coordinator::experiments;
use beacon_ptq::coordinator::report::{
    memory_table, metrics_table, pct, plan_table, planner_table,
};
use beacon_ptq::coordinator::{KernelBackend, Pipeline};
use beacon_ptq::obs::TrackingAlloc;
use beacon_ptq::quant::alphabet::BitWidth;
use beacon_ptq::util::cli::Args;

// Heap accounting for `--trace` runs: live/peak byte counters feed the
// MemoryReport and the trace's heap counter track. A few relaxed atomic
// ops per allocation — negligible next to the kernels.
#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Where to write the Chrome trace, if tracing was requested:
/// `--trace FILE`, bare `--trace` (default file name), or the
/// `BEACON_TRACE` env var.
fn trace_out(args: &Args) -> Option<PathBuf> {
    args.get("trace")
        .map(PathBuf::from)
        .or_else(|| args.switch("trace").then(|| PathBuf::from("beacon_trace.json")))
        .or_else(|| beacon_ptq::obs::trace_env().map(PathBuf::from))
}

fn pipeline(args: &Args) -> Result<Pipeline> {
    let dir = PathBuf::from(args.str("artifacts", "artifacts"));
    let model = args.str("model", "tiny-sim");
    let mut pipe = Pipeline::from_artifacts(&dir, &model)?;
    pipe.backend = match args.str("backend", "pjrt").as_str() {
        "pjrt" => KernelBackend::Pjrt,
        "native" => KernelBackend::Native,
        other => bail!("unknown backend '{other}' (pjrt|native)"),
    };
    Ok(pipe)
}

/// Assemble the plan builder for `quantize`: config file (with optional
/// `[layer "pattern"]` sections) → CLI flag overlay on the base →
/// `--override pattern=spec` entries, in that precedence order.
fn plan_builder(args: &Args) -> Result<PlanBuilder> {
    let mut builder = match args.get("config") {
        Some(path) => PlanBuilder::from_file(std::path::Path::new(path))?,
        None => PlanBuilder::uniform(&QuantConfig::default()),
    };
    builder.base_mut().apply_flags(&args.flags, &args.switches)?;
    for entry in args.list("override") {
        for part in entry.split(';').map(str::trim).filter(|s| !s.is_empty()) {
            let (pattern, spec) = part.split_once('=').ok_or_else(|| {
                anyhow::anyhow!("--override expects 'pattern=spec', got '{part}'")
            })?;
            builder.add_override(pattern.trim(), spec.trim())?;
        }
    }
    Ok(builder)
}

/// The planner search space from the CLI surface: `--budget-bits` plus
/// optional `--plan-methods m1,m2` / `--plan-bits b1,b2` /
/// `--plan-groups g1,g2` / `--plan-outliers k1,k2` comma lists.
fn search_space(args: &Args) -> Result<SearchSpace> {
    let budget: f64 = args
        .get("budget-bits")
        .ok_or_else(|| anyhow::anyhow!("--auto-plan needs --budget-bits <f64>"))?
        .parse()
        .map_err(|_| anyhow::anyhow!("--budget-bits expects a number"))?;
    let methods = args.get("plan-methods");
    let widths = args.get("plan-bits");
    let mut space = SearchSpace::parse(budget, methods, widths)?;
    if let Some(csv) = args.get("plan-groups") {
        space.set_group_sizes(csv)?;
    }
    if let Some(csv) = args.get("plan-outliers") {
        space.set_outlier_ks(csv)?;
    }
    Ok(space)
}

/// Default Table-1 grid: (bit width, K) as in the paper.
fn table_bits() -> Vec<(BitWidth, usize)> {
    vec![
        (BitWidth::B158, 6),
        (BitWidth::B2, 4),
        (BitWidth::B258, 4),
        (BitWidth::B3, 6),
        (BitWidth::B4, 4),
    ]
}

fn run() -> Result<()> {
    let args = Args::from_env();
    let trace = trace_out(&args);
    if trace.is_some() {
        beacon_ptq::obs::enable();
    }
    let result = dispatch(&args);
    if let Some(path) = trace {
        beacon_ptq::obs::write_chrome_trace(&path)?;
        println!("trace written to {} (open in ui.perfetto.dev)", path.display());
    }
    result
}

fn dispatch(args: &Args) -> Result<()> {
    let args = args.clone();
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    match sub.as_str() {
        "help" => {
            println!("{}", HELP);
            Ok(())
        }
        "info" => {
            let pipe = pipeline(&args)?;
            let m = &pipe.artifacts.manifest;
            println!("model        : {}", m.cfg.name);
            println!("params       : {}", m.cfg.param_count());
            println!("depth/d_model: {}/{}", m.cfg.depth, m.cfg.d_model);
            println!("quantizable  : {} layers", m.quantizable.len());
            println!("calib/eval   : {}/{} images", m.calib_count, m.eval_count);
            println!("platform     : {}", pipe.runtime.platform());
            println!("beacon HLO   : {:?}", m.beacon_layer.keys().collect::<Vec<_>>());
            Ok(())
        }
        "eval" => {
            let mut pipe = pipeline(&args)?;
            let fp = pipe.fp_top1()?;
            println!("FP top-1: {}%", pct(fp));
            if let Some(path) = args.get("load-packed") {
                let ps = beacon_ptq::model::PackedStore::load(
                    std::path::Path::new(&path),
                )?;
                let mut store = pipe.weights_fp.clone();
                for l in &ps.layers {
                    // PJRT needs dense f32 weight literals, so full
                    // expansion is unavoidable here — but it goes
                    // through the fused kernel's LUT expansion straight
                    // to row-major f32 (one f32 channel of scratch),
                    // never via an intermediate f64 matrix.
                    store.set_data(&l.name, l.dequant_f32());
                }
                println!(
                    "packed checkpoint {path}: {} layers, {} resident bytes",
                    ps.layers.len(),
                    ps.resident_bytes()
                );
                let top1 =
                    beacon_ptq::coordinator::eval::top1(&pipe, &store, 0)?;
                println!("packed top-1: {}%", pct(top1));
                println!("accuracy drop: {:.2}%", (fp - top1) * 100.0);
            }
            Ok(())
        }
        "quantize" => {
            let mut pipe = pipeline(&args)?;
            let builder = plan_builder(&args)?;
            let auto = args.switch("auto-plan") || args.get("budget-bits").is_some();
            let (plan, searched) = if auto {
                // config-file [layer "…"] sections land in the builder's
                // override list too — reject both sources, not just the
                // CLI flag, instead of silently discarding pinned layers
                if !builder.overrides().is_empty() {
                    bail!(
                        "--auto-plan searches the per-layer assignment itself; \
                         drop --override entries and [layer \"…\"] config sections \
                         (or run without --auto-plan)"
                    );
                }
                let space = search_space(&args)?;
                let (plan, preport) = pipe.auto_plan(builder.base(), &space)?;
                (plan, Some(preport))
            } else {
                (builder.build(pipe.quantizable())?, None)
            };
            println!(
                "running {} (backend {:?}, {} threads)...",
                plan.label(),
                pipe.backend,
                beacon_ptq::util::pool::resolve_threads(plan.base.threads)
            );
            if let Some(out) = args.get("save-plan") {
                std::fs::write(out, plan.to_manifest())?;
                println!("saved resolved plan manifest to {out}");
            }
            let want_packed = args.get("save-packed").is_some();
            let (mut report, store, packed) = if want_packed {
                pipe.quantize_packed(&plan)?
            } else {
                let (r, s) = pipe.quantize_with_weights(&plan)?;
                (r, s, None)
            };
            report.planner = searched;
            println!("FP top-1      : {}%", pct(report.fp_top1));
            println!("quant top-1   : {}%", pct(report.top1));
            println!("accuracy drop : {:.2}%", report.accuracy_drop());
            println!("effective bits: {:.2} / weight", report.effective_bits);
            println!("quantize time : {:.2}s  eval time: {:.2}s",
                report.quantize_secs, report.eval_secs);
            if args.switch("verbose") {
                if let Some(preport) = &report.planner {
                    println!("\n{}", planner_table(preport).render());
                }
                println!("\n{}", plan_table(&report).render());
                if let Some(m) = &report.metrics {
                    println!("\n{}", metrics_table(m).render());
                }
                if let Some(mem) = &report.memory {
                    println!("\n{}", memory_table(mem).render());
                }
                if !report.ln_tune_losses.is_empty() {
                    println!("ln-tune loss: {:?}", report.ln_tune_losses);
                }
            }
            if let Some(out) = args.get("save") {
                store.save(std::path::Path::new(out))?;
                println!("saved quantized weights to {out}");
            }
            if let Some(out) = args.get("save-packed") {
                match packed {
                    Some(ps) => {
                        ps.save(std::path::Path::new(&out))?;
                        let f32_bytes: u64 = ps
                            .layers
                            .iter()
                            .map(|l| (l.rows * l.cols() * 4) as u64)
                            .sum();
                        println!(
                            "saved packed checkpoint to {out} \
                             ({} resident bytes vs {} as f32, {:.2}×)",
                            ps.resident_bytes(),
                            f32_bytes,
                            ps.resident_bytes() as f64 / f32_bytes as f64
                        );
                    }
                    None => bail!(
                        "--save-packed: a layer's codes fell off the storage \
                         grid, no packed checkpoint written"
                    ),
                }
            }
            Ok(())
        }
        "plan" => {
            // search-only: probe + allocate + emit the manifest, no
            // quantization run
            let mut pipe = pipeline(&args)?;
            let space = search_space(&args)?;
            let builder = plan_builder(&args)?;
            if !builder.overrides().is_empty() {
                bail!(
                    "the plan search takes no --override entries or \
                     [layer \"…\"] config sections"
                );
            }
            let (plan, preport) = pipe.auto_plan(builder.base(), &space)?;
            println!("{}", planner_table(&preport).render());
            println!(
                "searched plan: {} ({:.3} effective bits / budget {:.2})",
                plan.label(),
                preport.effective_bits,
                preport.budget_bits
            );
            match args.get("save-plan") {
                Some(out) => {
                    std::fs::write(out, plan.to_manifest())?;
                    println!("saved searched plan manifest to {out}");
                }
                None => println!("\n{}", plan.to_manifest()),
            }
            Ok(())
        }
        "budget-sweep" => {
            let mut pipe = pipeline(&args)?;
            let builder = plan_builder(&args)?;
            let budgets: Vec<f64> = {
                let csv = args.csv("budgets");
                if csv.is_empty() {
                    vec![2.0, 2.58, 3.0, 4.0]
                } else {
                    csv.iter()
                        .map(|s| {
                            s.parse().map_err(|_| {
                                anyhow::anyhow!("--budgets expects numbers, got '{s}'")
                            })
                        })
                        .collect::<Result<_>>()?
                }
            };
            // candidate grid from --plan-methods/--plan-bits; the budget
            // slot is replaced per sweep row
            let template = SearchSpace::parse(
                budgets[0],
                args.get("plan-methods"),
                args.get("plan-bits"),
            )?;
            let table = experiments::budget_sweep(
                &mut pipe,
                builder.base(),
                &template,
                &budgets,
            )?;
            println!("{}", table.render());
            Ok(())
        }
        "table1" => {
            let mut pipe = pipeline(&args)?;
            let (table, _) = experiments::table1(&mut pipe, &table_bits())?;
            println!("{}", table.render());
            Ok(())
        }
        "table2" => {
            let mut pipe = pipeline(&args)?;
            let grid = vec![
                (BitWidth::B2, 4usize),
                (BitWidth::B3, 6),
                (BitWidth::B4, 4),
            ];
            let (table, _) = experiments::table2(&mut pipe, &grid)?;
            println!("{}", table.render());
            Ok(())
        }
        "convergence" => {
            let mut pipe = pipeline(&args)?;
            let table = experiments::convergence(&mut pipe, args.usize("max-loops", 8))?;
            println!("{}", table.render());
            Ok(())
        }
        "ablate-calib" => {
            let mut pipe = pipeline(&args)?;
            let sizes = [8, 16, 32, 64, 128];
            let table = experiments::ablate_calib(&mut pipe, &sizes)?;
            println!("{}", table.render());
            Ok(())
        }
        "ablate-ec" => {
            let mut pipe = pipeline(&args)?;
            let bits = BitWidth::parse(&args.str("bits", "2")).unwrap();
            let table = experiments::ablate_ec(&mut pipe, bits)?;
            println!("{}", table.render());
            Ok(())
        }
        "runtime-row" => {
            let mut pipe = pipeline(&args)?;
            let bits = BitWidth::parse(&args.str("bits", "2")).unwrap();
            let table = experiments::runtime_row(&mut pipe, bits, args.usize("loops", 4))?;
            println!("{}", table.render());
            Ok(())
        }
        other => bail!("unknown subcommand '{other}'\n{HELP}"),
    }
}

const HELP: &str = "beacon — Beacon PTQ coordinator
usage: beacon <info|eval|quantize|plan|budget-sweep|table1|table2|convergence|ablate-calib|ablate-ec|runtime-row> [flags]
flags: --artifacts DIR --model NAME --backend pjrt|native --config FILE
       --method beacon|gptq|rtn|comq --bits B --loops K --ec --centering
       --ln_tune --threads N --save OUT.bin --save-plan PLAN.cfg --verbose
       --save-packed OUT.bpk  write the low-bit BPK1 packed checkpoint
       eval --load-packed F.bpk  evaluate a packed checkpoint end-to-end
       --trace [FILE]  write a Chrome trace (Perfetto / chrome://tracing)
                       of the run, with a heap counter track; BEACON_TRACE=FILE
                       does the same. --verbose adds metrics + memory tables
plans: --override 'pattern=spec' (repeatable; ';'-separated list ok)
       spec = method[:bits][+gN|+asym|+sym|+kN|+ec|+noec|+centering|+nocentering|+loops=K|+damp=F]
       +gN groups scales every N rows, +asym adds per-group offsets,
       +kN keeps the top-k |w| outliers per channel exact (f32 sidecar)
       e.g. --override 'blocks.*.qkv.w=beacon:2+ec' --override 'attn.*=beacon:3+g16+asym+k2'
       config files take the same overrides as [layer \"pattern\"] sections
search: quantize --auto-plan --budget-bits B  (greedy loss-aware bit allocation)
       plan --budget-bits B --save-plan OUT.cfg   (search only, emit manifest)
       budget-sweep --budgets 2,2.58,3,4          (searched vs uniform table)
       --plan-methods m1,m2 / --plan-bits b1,b2 narrow the probe grid
       --plan-groups g1,g2 / --plan-outliers k1,k2 add scenario axes
       (gptq probes stay dense; grouped/outlier combos are skipped for it)";
