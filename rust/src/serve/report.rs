//! The serving scoreboard: latency/throughput/batch-shape summary
//! emitted by [`crate::serve::Server::shutdown`] and rendered as a
//! table by `coordinator::report::serve_table`, exactly like
//! `QuantReport` sections.

use crate::obs::HistSummary;

/// End-of-run serving statistics. Latency quantiles come from the
/// obs `Hist` log-bucket histograms (±50% bucket midpoints, exact
/// min/max); the batch-size distribution is exact.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Caller-chosen label (e.g. "closed 4-bit").
    pub label: String,
    pub requests: u64,
    pub batches: u64,
    /// Wall-clock seconds from server start to shutdown.
    pub wall_secs: f64,
    /// Worker threads actually spawned (after the engine-plan split).
    pub workers: usize,
    /// GEMM threads each worker hands to the fused kernel.
    pub gemm_threads: usize,
    pub max_batch: usize,
    pub deadline_ms: f64,
    pub queue_capacity: usize,
    /// End-to-end per-request latency (submit → response), ns.
    pub latency_ns: HistSummary,
    /// Time a request waited before its batch was dispatched, ns.
    pub queue_wait_ns: HistSummary,
    /// Per-batch forward time, ns.
    pub service_ns: HistSummary,
    /// Exact batch-size → count distribution, ascending by size.
    pub batch_sizes: Vec<(usize, u64)>,
    /// Absolute tracked-allocator peak at shutdown (0 when the tracking
    /// allocator is not installed). Callers scope it to a phase with
    /// `obs::memory::reset_peak()` before starting the server.
    pub peak_heap_bytes: u64,
}

impl ServeReport {
    pub fn requests_per_sec(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.requests as f64 / self.wall_secs
        } else {
            0.0
        }
    }

    /// Mean requests per dispatched batch.
    pub fn mean_batch(&self) -> f64 {
        if self.batches > 0 {
            self.requests as f64 / self.batches as f64
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ServeReport {
        ServeReport {
            label: "test".into(),
            requests: 30,
            batches: 10,
            wall_secs: 2.0,
            workers: 2,
            gemm_threads: 1,
            max_batch: 8,
            deadline_ms: 2.0,
            queue_capacity: 64,
            latency_ns: HistSummary {
                count: 30,
                p50: 100,
                p95: 200,
                p99: 300,
                mean: 120,
                min: 50,
                max: 400,
            },
            queue_wait_ns: HistSummary {
                count: 30,
                p50: 10,
                p95: 20,
                p99: 30,
                mean: 12,
                min: 5,
                max: 40,
            },
            service_ns: HistSummary {
                count: 10,
                p50: 80,
                p95: 90,
                p99: 95,
                mean: 82,
                min: 70,
                max: 99,
            },
            batch_sizes: vec![(2, 5), (4, 5)],
            peak_heap_bytes: 0,
        }
    }

    #[test]
    fn derived_rates() {
        let r = sample();
        assert_eq!(r.requests_per_sec(), 15.0);
        assert_eq!(r.mean_batch(), 3.0);
        let empty = ServeReport {
            requests: 0,
            batches: 0,
            wall_secs: 0.0,
            ..sample()
        };
        assert_eq!(empty.requests_per_sec(), 0.0);
        assert_eq!(empty.mean_batch(), 0.0);
    }
}
