//! The request queue, dynamic batcher, and worker pool. See the module
//! doc in [`crate::serve`] for the architecture picture and
//! `docs/SERVE.md` for the design note.
//!
//! Determinism contract: a response is a pure function of the request
//! vector and the packed model. Batching, worker count, GEMM thread
//! count, and deadline only change *when* a request runs, never what it
//! returns — every output is bit-identical to
//! [`PackedModel::forward_one`] on that request alone.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::obs;
use crate::obs::Hist;
use crate::quant::engine;
use crate::serve::{PackedModel, ServeReport};
use crate::util::pool::resolve_threads;

/// Server tuning knobs. `Default` matches the CLI/load_gen defaults.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Label stamped on the emitted [`ServeReport`].
    pub label: String,
    /// Flush a batch once it holds this many requests.
    pub max_batch: usize,
    /// ... or once this long has passed since the batch's first
    /// request arrived, whichever comes first.
    pub deadline: Duration,
    /// Worker threads; 0 = derive from the thread budget.
    pub workers: usize,
    /// Total thread budget; 0 = auto (`BEACON_THREADS` / cores). Split
    /// into `workers × gemm_threads` by [`engine::plan`], the same
    /// idiom the quantize engine uses for its layer/channel split.
    pub threads: usize,
    /// Bound of the request queue — submits block (or `try_submit`
    /// returns `Full`) beyond this many queued requests.
    pub queue_capacity: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            label: "serve".to_string(),
            max_batch: 8,
            deadline: Duration::from_millis(2),
            workers: 0,
            threads: 0,
            queue_capacity: 64,
        }
    }
}

/// One completed inference.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub output: Vec<f64>,
    /// Requests in the batch this one rode in.
    pub batch_size: usize,
    /// Submit → batch pickup by a worker.
    pub queue_wait: Duration,
    /// The batch's fused-forward time.
    pub service: Duration,
}

/// Why [`ServeClient::try_submit`] could not enqueue; both variants
/// hand the input vector back so the caller can retry.
#[derive(Debug, PartialEq)]
pub enum TrySubmitError {
    /// Queue at capacity — backpressure.
    Full(Vec<f64>),
    /// Server threads are gone.
    Closed(Vec<f64>),
}

struct Request {
    id: u64,
    input: Vec<f64>,
    enqueued: Instant,
    resp: mpsc::Sender<Response>,
}

/// Ticket for one in-flight request; [`ResponseHandle::wait`] blocks
/// until the worker delivers.
#[derive(Debug)]
pub struct ResponseHandle {
    pub id: u64,
    rx: mpsc::Receiver<Response>,
}

impl ResponseHandle {
    pub fn wait(self) -> Response {
        self.rx.recv().expect("serve: server dropped an in-flight request")
    }
}

/// Cloneable submission endpoint. Dropping every clone is the shutdown
/// signal: the batcher drains what is queued and exits.
#[derive(Clone)]
pub struct ServeClient {
    tx: SyncSender<Request>,
    next_id: Arc<AtomicU64>,
    input_dim: usize,
}

impl ServeClient {
    pub fn input_dim(&self) -> usize {
        self.input_dim
    }

    fn request(&self, input: Vec<f64>) -> (Request, ResponseHandle) {
        assert_eq!(input.len(), self.input_dim, "request feature count");
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let req =
            Request { id, input, enqueued: Instant::now(), resp: tx };
        (req, ResponseHandle { id, rx })
    }

    /// Enqueue, blocking while the queue is at capacity (closed-loop
    /// clients self-throttle through this).
    pub fn submit(&self, input: Vec<f64>) -> ResponseHandle {
        let (req, handle) = self.request(input);
        self.tx.send(req).expect("serve: server is gone");
        handle
    }

    /// Non-blocking enqueue; open-loop generators use this to observe
    /// backpressure instead of stalling their arrival clock.
    pub fn try_submit(
        &self,
        input: Vec<f64>,
    ) -> Result<ResponseHandle, TrySubmitError> {
        let (req, handle) = self.request(input);
        match self.tx.try_send(req) {
            Ok(()) => Ok(handle),
            Err(TrySendError::Full(r)) => Err(TrySubmitError::Full(r.input)),
            Err(TrySendError::Disconnected(r)) => {
                Err(TrySubmitError::Closed(r.input))
            }
        }
    }
}

#[derive(Default)]
struct ServeStats {
    latency: Hist,
    queue_wait: Hist,
    service: Hist,
    batch_sizes: BTreeMap<usize, u64>,
    batches: u64,
    requests: u64,
}

/// The running server: batcher + workers over an `Arc`-shared
/// [`PackedModel`]. Obtain one from [`Server::start`]; finish with
/// [`Server::shutdown`] *after* dropping every [`ServeClient`] clone.
pub struct Server {
    batcher: JoinHandle<()>,
    workers: Vec<JoinHandle<()>>,
    stats: Arc<Mutex<ServeStats>>,
    started: Instant,
    cfg: ServeConfig,
    nworkers: usize,
    gemm_threads: usize,
}

impl Server {
    /// Spawn the batcher and workers. Thread sizing reuses the engine
    /// scheduler: the total budget (`cfg.threads`, 0 = auto) splits
    /// into `workers × gemm_threads` via [`engine::plan`] with the
    /// requested worker count as the outer ("layer") axis.
    pub fn start(
        model: Arc<PackedModel>,
        cfg: ServeConfig,
    ) -> (Server, ServeClient) {
        let total = resolve_threads(cfg.threads);
        let workers_req =
            if cfg.workers == 0 { total } else { cfg.workers };
        let sched = engine::plan(total, workers_req, true);
        let nworkers = sched.layer_threads;
        let gemm_threads = sched.channel_threads;

        obs::memory::set_resident(
            "serve.packed_model",
            model.resident_bytes(),
        );

        let (req_tx, req_rx) =
            mpsc::sync_channel::<Request>(cfg.queue_capacity.max(1));
        let (batch_tx, batch_rx) =
            mpsc::sync_channel::<Vec<Request>>(nworkers);
        let batch_rx = Arc::new(Mutex::new(batch_rx));

        let client = ServeClient {
            tx: req_tx,
            next_id: Arc::new(AtomicU64::new(0)),
            input_dim: model.input_dim(),
        };

        let batcher = {
            let (max_batch, deadline) = (cfg.max_batch.max(1), cfg.deadline);
            std::thread::Builder::new()
                .name("serve.batcher".to_string())
                .spawn(move || batcher_loop(req_rx, batch_tx, max_batch, deadline))
                .expect("serve: spawn batcher")
        };

        let stats = Arc::new(Mutex::new(ServeStats::default()));
        let workers = (0..nworkers)
            .map(|wi| {
                let model = Arc::clone(&model);
                let batch_rx = Arc::clone(&batch_rx);
                let stats = Arc::clone(&stats);
                std::thread::Builder::new()
                    .name(format!("serve.worker.{wi}"))
                    .spawn(move || {
                        worker_loop(&model, &batch_rx, &stats, gemm_threads)
                    })
                    .expect("serve: spawn worker")
            })
            .collect();

        let server = Server {
            batcher,
            workers,
            stats,
            started: Instant::now(),
            cfg,
            nworkers,
            gemm_threads,
        };
        (server, client)
    }

    pub fn workers(&self) -> usize {
        self.nworkers
    }

    pub fn gemm_threads(&self) -> usize {
        self.gemm_threads
    }

    /// Join everything and summarize. Graceful-drain contract: blocks
    /// until the batcher has flushed every queued request (including a
    /// final partial batch) and the workers have answered all of them —
    /// callers must drop their [`ServeClient`] clones first or this
    /// waits forever.
    pub fn shutdown(self) -> ServeReport {
        self.batcher.join().expect("serve: batcher panicked");
        for w in self.workers {
            w.join().expect("serve: worker panicked");
        }
        let wall_secs = self.started.elapsed().as_secs_f64();
        let stats = self.stats.lock().unwrap();
        ServeReport {
            label: self.cfg.label.clone(),
            requests: stats.requests,
            batches: stats.batches,
            wall_secs,
            workers: self.nworkers,
            gemm_threads: self.gemm_threads,
            max_batch: self.cfg.max_batch,
            deadline_ms: self.cfg.deadline.as_secs_f64() * 1e3,
            queue_capacity: self.cfg.queue_capacity,
            latency_ns: stats.latency.summary(),
            queue_wait_ns: stats.queue_wait.summary(),
            service_ns: stats.service.summary(),
            batch_sizes: stats
                .batch_sizes
                .iter()
                .map(|(&size, &count)| (size, count))
                .collect(),
            peak_heap_bytes: obs::memory::peak_bytes(),
        }
    }
}

/// Collect requests into batches: block for the first request, then
/// keep accepting until the batch holds `max_batch` requests or
/// `deadline` has passed since the first one arrived. Exits when every
/// client sender is gone and the queue is drained.
fn batcher_loop(
    req_rx: Receiver<Request>,
    batch_tx: SyncSender<Vec<Request>>,
    max_batch: usize,
    deadline: Duration,
) {
    while let Ok(first) = req_rx.recv() {
        let flush_at = Instant::now() + deadline;
        let mut batch = vec![first];
        while batch.len() < max_batch {
            let left = flush_at.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break;
            }
            match req_rx.recv_timeout(left) {
                Ok(req) => batch.push(req),
                // Timeout = deadline hit; Disconnected = clients gone —
                // either way this batch is as full as it gets.
                Err(_) => break,
            }
        }
        if batch_tx.send(batch).is_err() {
            return; // workers gone — nothing left to answer to
        }
    }
}

/// Pull batches, run the fused forward, deliver per-request responses,
/// and fold the batch's timings into the shared stats. Exits when the
/// batcher hangs up.
fn worker_loop(
    model: &PackedModel,
    batch_rx: &Mutex<Receiver<Vec<Request>>>,
    stats: &Mutex<ServeStats>,
    gemm_threads: usize,
) {
    loop {
        // The temporary guard drops before processing, so other workers
        // can pull the next batch while this one computes.
        let batch = match batch_rx.lock().unwrap().recv() {
            Ok(b) => b,
            Err(_) => return,
        };
        let picked = Instant::now();
        let n = batch.len();
        let dim = model.input_dim();
        let mut flat = Vec::with_capacity(n * dim);
        for req in &batch {
            flat.extend_from_slice(&req.input);
        }
        let x = crate::linalg::Matrix::from_vec(n, dim, flat);

        let sp = obs::span_args("serve", || {
            ("batch".to_string(), vec![("size", n.to_string())])
        });
        let out = model.forward_batch(&x, gemm_threads);
        let service = Duration::from_secs_f64(sp.finish());

        let mut local = ServeStats {
            batches: 1,
            requests: n as u64,
            ..ServeStats::default()
        };
        *local.batch_sizes.entry(n).or_insert(0) += 1;
        local.service.record(service.as_nanos() as u64);
        for (r, req) in batch.into_iter().enumerate() {
            let queue_wait = picked.duration_since(req.enqueued);
            local.queue_wait.record(queue_wait.as_nanos() as u64);
            local.latency.record(req.enqueued.elapsed().as_nanos() as u64);
            // a client that dropped its handle just doesn't get a reply
            let _ = req.resp.send(Response {
                id: req.id,
                output: out.row(r).to_vec(),
                batch_size: n,
                queue_wait,
                service,
            });
        }
        obs::merge_hist("serve.queue_wait_ns", local.queue_wait.clone());
        obs::merge_hist("serve.service_ns", local.service.clone());
        obs::counter("serve.requests", n as u64);

        let mut s = stats.lock().unwrap();
        s.latency.merge(&local.latency);
        s.queue_wait.merge(&local.queue_wait);
        s.service.merge(&local.service);
        for (&size, &count) in &local.batch_sizes {
            *s.batch_sizes.entry(size).or_insert(0) += count;
        }
        s.batches += local.batches;
        s.requests += local.requests;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::SplitMix64;
    use crate::quant::alphabet::BitWidth;
    use crate::serve::synthetic_store;
    use crate::util::prop::Gen;

    fn model() -> Arc<PackedModel> {
        Arc::new(
            PackedModel::from_store(synthetic_store(
                2,
                24,
                BitWidth::B4,
                0x5E,
            ))
            .unwrap(),
        )
    }

    #[test]
    fn responses_match_forward_one_bitwise() {
        let m = model();
        let (server, client) = Server::start(
            Arc::clone(&m),
            ServeConfig { workers: 2, threads: 2, ..Default::default() },
        );
        let mut g = Gen { rng: SplitMix64::new(3) };
        let inputs: Vec<Vec<f64>> =
            (0..12).map(|_| g.vec_normal(m.input_dim(), 1.0)).collect();
        let handles: Vec<ResponseHandle> =
            inputs.iter().map(|x| client.submit(x.clone())).collect();
        drop(client);
        for (x, h) in inputs.iter().zip(handles) {
            let id = h.id;
            let got = h.wait();
            assert_eq!(got.id, id);
            let want = m.forward_one(x, 1);
            assert_eq!(got.output.len(), want.len());
            for (a, b) in got.output.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            assert!(got.batch_size >= 1);
        }
        let report = server.shutdown();
        assert_eq!(report.requests, 12);
        assert!(report.batches >= 1);
        let counted: u64 =
            report.batch_sizes.iter().map(|&(s, c)| s as u64 * c).sum();
        assert_eq!(counted, 12);
    }

    #[test]
    fn engine_plan_sizes_the_worker_split() {
        let m = model();
        let (server, client) = Server::start(
            Arc::clone(&m),
            ServeConfig { workers: 2, threads: 8, ..Default::default() },
        );
        assert_eq!(server.workers(), 2);
        assert_eq!(server.gemm_threads(), 4);
        drop(client);
        server.shutdown();

        let (server, client) = Server::start(
            m,
            ServeConfig { workers: 1, threads: 4, ..Default::default() },
        );
        assert_eq!(server.workers(), 1);
        assert_eq!(server.gemm_threads(), 4);
        drop(client);
        server.shutdown();
    }

    #[test]
    fn try_submit_reports_full_and_closed_with_input_back() {
        // A hand-built client over a rendezvous channel nobody reads:
        // deterministic Full. Dropping the receiver: deterministic
        // Closed.
        let (tx, rx) = mpsc::sync_channel::<Request>(1);
        let client = ServeClient {
            tx,
            next_id: Arc::new(AtomicU64::new(0)),
            input_dim: 2,
        };
        assert!(client.try_submit(vec![1.0, 2.0]).is_ok()); // fills slot
        match client.try_submit(vec![3.0, 4.0]) {
            Err(TrySubmitError::Full(v)) => assert_eq!(v, vec![3.0, 4.0]),
            other => panic!("want Full, got {other:?}"),
        }
        drop(rx);
        match client.try_submit(vec![5.0, 6.0]) {
            Err(TrySubmitError::Closed(v)) => assert_eq!(v, vec![5.0, 6.0]),
            other => panic!("want Closed, got {other:?}"),
        }
    }

    #[test]
    fn report_carries_config_and_split() {
        let (server, client) = Server::start(
            model(),
            ServeConfig {
                label: "unit".to_string(),
                max_batch: 4,
                deadline: Duration::from_millis(1),
                workers: 1,
                threads: 1,
                queue_capacity: 16,
            },
        );
        drop(client);
        let r = server.shutdown();
        assert_eq!(r.label, "unit");
        assert_eq!(r.max_batch, 4);
        assert_eq!(r.deadline_ms, 1.0);
        assert_eq!(r.queue_capacity, 16);
        assert_eq!(r.workers, 1);
        assert_eq!(r.requests, 0);
    }
}
