//! Synthetic packed checkpoints for load generation and tests: random
//! on-grid codes packed directly via [`PackedLayer::pack`] — no
//! quantization pass, so a serving fixture costs milliseconds to build
//! while exercising exactly the BPK1 + fused-kernel path real
//! checkpoints use.

use crate::data::rng::SplitMix64;
use crate::model::{PackedLayer, PackedStore};
use crate::quant::alphabet::{alphabet, BitWidth};
use crate::util::prop::Gen;

/// Build a chained `layers × (dim×dim)` packed store at `width`. Codes
/// are drawn uniformly from the width's alphabet; per-channel scales
/// are ~1/√dim so chained activations stay near unit scale (no
/// overflow/underflow drift across layers). Deterministic in `seed`.
pub fn synthetic_store(
    layers: usize,
    dim: usize,
    width: BitWidth,
    seed: u64,
) -> PackedStore {
    let alph = alphabet(width);
    let mut g = Gen { rng: SplitMix64::new(seed) };
    let store_layers = (0..layers)
        .map(|li| {
            let codes: Vec<Vec<f64>> = (0..dim)
                .map(|_| (0..dim).map(|_| *g.pick(&alph)).collect())
                .collect();
            let scales: Vec<f64> = (0..dim)
                .map(|_| g.f64_in(0.5, 1.5) / (dim as f64).sqrt())
                .collect();
            let offsets = vec![0.0f64; dim];
            PackedLayer::pack(
                &format!("serve.layer.{li}.w"),
                &codes,
                &scales,
                &offsets,
                width,
            )
            .expect("alphabet codes are on-grid by construction")
        })
        .collect();
    PackedStore { layers: store_layers }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let a = synthetic_store(2, 24, BitWidth::B2, 42);
        let b = synthetic_store(2, 24, BitWidth::B2, 42);
        assert_eq!(a.layers.len(), 2);
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            assert_eq!(la.name, lb.name);
            for (ca, cb) in la.channels.iter().zip(&lb.channels) {
                assert_eq!(ca.words, cb.words);
                assert_eq!(ca.scale.to_bits(), cb.scale.to_bits());
            }
        }
        let c = synthetic_store(2, 24, BitWidth::B2, 43);
        assert_ne!(
            a.layers[0].channels[0].words,
            c.layers[0].channels[0].words
        );
    }

    #[test]
    fn layers_chain_square() {
        let s = synthetic_store(3, 16, BitWidth::B4, 7);
        for l in &s.layers {
            assert_eq!(l.rows, 16);
            assert_eq!(l.cols(), 16);
        }
    }
}
