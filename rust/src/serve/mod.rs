//! The serving subsystem: an async batching inference server over
//! resident packed weights — ROADMAP item 2, the "millions of users"
//! half of the north star.
//!
//! Architecture (design note in `docs/SERVE.md`):
//!
//! ```text
//! clients ──submit──▶ bounded request queue ──▶ dynamic batcher
//!                     (sync_channel, blocks        (flush at max_batch
//!                      when full = backpressure)    OR deadline)
//!                                                      │ batches
//!                                            worker threads × N
//!                                            (fused packed GEMM on the
//!                                             Arc-shared PackedModel)
//!                                                      │ per-request
//!                                            response channels
//! ```
//!
//! * **Bounded queue.** [`ServeClient::submit`] blocks when the queue is
//!   at capacity — closed-loop clients self-throttle and open-loop
//!   generators feel backpressure instead of ballooning memory.
//! * **Dynamic batcher.** One thread collects requests into a batch and
//!   flushes when the batch reaches `max_batch` requests **or** the
//!   deadline since the batch's first request elapses, whichever comes
//!   first.
//! * **Workers.** Sized with the engine scheduler's thread-budget idiom
//!   ([`crate::quant::engine::plan`]): one total thread budget splits
//!   into `workers × gemm_threads`, exactly like the quantizer's
//!   layer/channel split.
//! * **Determinism.** [`crate::linalg::packed_gemm`] computes every
//!   batch row as an independent [`crate::linalg::matrix::dot`] against
//!   the expanded channel, so each response is **bit-identical** to the
//!   sequential single-request path ([`PackedModel::forward_one`])
//!   regardless of batch composition, worker count, or deadline.
//! * **No weight matrices.** The [`PackedModel`] holds only BPK1 bit
//!   streams plus per-channel dequant LUTs; all compute goes through the
//!   fused unpack-dequant kernel.
//!
//! Shutdown contract: drop every [`ServeClient`] clone, then call
//! [`Server::shutdown`]. The batcher drains the queue (flushing the
//! final partial batch), workers finish every dispatched batch, and the
//! returned [`ServeReport`] accounts for exactly the submitted requests
//! — nothing dropped, nothing duplicated.

pub mod model;
pub mod report;
pub mod server;
pub mod synth;

pub use model::PackedModel;
pub use report::ServeReport;
pub use server::{
    Response, ResponseHandle, ServeClient, ServeConfig, Server,
    TrySubmitError,
};
pub use synth::synthetic_store;
