//! The resident serving model: a [`PackedStore`] plus pre-built dequant
//! LUTs, shared across worker threads via `Arc`. All compute routes
//! through the fused unpack-dequant kernel — no f32/f64 weight matrix is
//! ever materialized.

use std::path::Path;

use anyhow::{bail, Result};

use crate::linalg::{packed_gemm, packed_matvec_threads, Matrix};
use crate::model::{PackedLayer, PackedStore};

/// A packed checkpoint prepared for serving: layers are chained
/// (`layer[l].cols == layer[l+1].rows`, validated at construction) and
/// each layer's per-channel dequant LUTs are built once and reused for
/// every request.
#[derive(Debug)]
pub struct PackedModel {
    store: PackedStore,
    /// `luts[l][j]` = dequant LUT of layer `l`, channel `j`
    luts: Vec<Vec<Vec<f32>>>,
}

impl PackedModel {
    /// Wrap a loaded store for serving. Fails when the store is empty or
    /// the layer dimensions do not chain.
    pub fn from_store(store: PackedStore) -> Result<PackedModel> {
        if store.layers.is_empty() {
            bail!("packed model has no layers");
        }
        for win in store.layers.windows(2) {
            if win[0].cols() != win[1].rows {
                bail!(
                    "packed model layers do not chain: '{}' emits {} \
                     features but '{}' expects {}",
                    win[0].name,
                    win[0].cols(),
                    win[1].name,
                    win[1].rows
                );
            }
        }
        let luts: Vec<Vec<Vec<f32>>> =
            store.layers.iter().map(PackedLayer::luts).collect();
        Ok(PackedModel { store, luts })
    }

    /// Load a BPK1 checkpoint and prepare it for serving.
    pub fn load(path: &Path) -> Result<PackedModel> {
        PackedModel::from_store(PackedStore::load(path)?)
    }

    /// Feature count a request vector must carry.
    pub fn input_dim(&self) -> usize {
        self.store.layers[0].rows
    }

    /// Feature count of a response vector.
    pub fn output_dim(&self) -> usize {
        self.store.layers.last().map_or(0, PackedLayer::cols)
    }

    pub fn layer_count(&self) -> usize {
        self.store.layers.len()
    }

    pub fn store(&self) -> &PackedStore {
        &self.store
    }

    /// Heap footprint of the resident model: packed bit streams plus the
    /// pre-built LUTs (for the resident-bytes registry).
    pub fn resident_bytes(&self) -> u64 {
        let lut_bytes: u64 = self
            .luts
            .iter()
            .flatten()
            .map(|l| (l.len() * 4 + std::mem::size_of::<Vec<f32>>()) as u64)
            .sum();
        self.store.resident_bytes() + lut_bytes
    }

    /// Forward a batch: rows of `x` are independent requests. Each layer
    /// runs the fused [`packed_gemm`], so every output row is
    /// bit-identical to [`PackedModel::forward_one`] on that row alone —
    /// batching never changes a response.
    pub fn forward_batch(&self, x: &Matrix, threads: usize) -> Matrix {
        assert_eq!(x.cols, self.input_dim(), "request feature count");
        let mut act: Option<Matrix> = None;
        for (l, layer) in self.store.layers.iter().enumerate() {
            let cols = layer.kernel_cols(&self.luts[l]);
            let input = act.as_ref().unwrap_or(x);
            act = Some(packed_gemm(&cols, input, threads));
        }
        act.expect("from_store rejects empty models")
    }

    /// The sequential single-request reference path: one fused matvec
    /// per layer. Thread-count invariant (index-order gather), so this
    /// is the determinism oracle the batched path is checked against.
    pub fn forward_one(&self, x: &[f64], threads: usize) -> Vec<f64> {
        assert_eq!(x.len(), self.input_dim(), "request feature count");
        let mut act: Option<Vec<f64>> = None;
        for (l, layer) in self.store.layers.iter().enumerate() {
            let cols = layer.kernel_cols(&self.luts[l]);
            let input = act.as_deref().unwrap_or(x);
            act = Some(packed_matvec_threads(&cols, input, threads));
        }
        act.expect("from_store rejects empty models")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::SplitMix64;
    use crate::quant::alphabet::BitWidth;
    use crate::serve::synthetic_store;
    use crate::util::prop::Gen;

    fn model() -> PackedModel {
        PackedModel::from_store(synthetic_store(3, 32, BitWidth::B4, 0xA11))
            .unwrap()
    }

    #[test]
    fn rejects_empty_and_unchained_stores() {
        assert!(PackedModel::from_store(PackedStore::default()).is_err());
        let a = synthetic_store(1, 16, BitWidth::B2, 1).layers.remove(0);
        let b = synthetic_store(1, 24, BitWidth::B2, 2).layers.remove(0);
        let err = PackedModel::from_store(PackedStore { layers: vec![a, b] })
            .unwrap_err();
        assert!(format!("{err:#}").contains("chain"), "{err:#}");
    }

    #[test]
    fn batch_rows_bit_identical_to_forward_one() {
        let m = model();
        let mut g = Gen { rng: SplitMix64::new(7) };
        let (b, n) = (5usize, m.input_dim());
        let x = Matrix::from_vec(b, n, g.vec_normal(b * n, 1.0));
        for threads in [1usize, 4] {
            let batched = m.forward_batch(&x, threads);
            for r in 0..b {
                let single = m.forward_one(x.row(r), 1);
                for (j, want) in single.iter().enumerate() {
                    assert_eq!(
                        batched[(r, j)].to_bits(),
                        want.to_bits(),
                        "t={threads} row {r} ch {j}"
                    );
                }
            }
        }
    }

    #[test]
    fn forward_one_thread_invariant() {
        let m = model();
        let mut g = Gen { rng: SplitMix64::new(9) };
        let x = g.vec_normal(m.input_dim(), 1.0);
        let t1 = m.forward_one(&x, 1);
        let t4 = m.forward_one(&x, 4);
        for (a, b) in t1.iter().zip(&t4) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn resident_counts_streams_and_luts() {
        let m = model();
        assert!(m.resident_bytes() > m.store().resident_bytes());
        assert_eq!(m.input_dim(), 32);
        assert_eq!(m.output_dim(), 32);
        assert_eq!(m.layer_count(), 3);
    }
}
