//! splitmix64 — the cross-language deterministic RNG.
//!
//! EXACT mirror of `python/compile/common.py` (same constants, same draw
//! order); the golden values in the tests below are duplicated in
//! `python/tests/test_rng_data.py` and pin the contract.

pub const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Order-sensitive seed combiner.
#[inline]
pub fn combine(a: u64, b: u64) -> u64 {
    mix64(a ^ mix64(b.wrapping_add(GOLDEN)))
}

#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(GOLDEN);
        mix64(self.state)
    }

    /// Uniform in [0, 1) with 24 bits of entropy — exactly representable in
    /// f32, so the Python and Rust streams agree bit-for-bit.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u32 << 24) as f32
    }

    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn fill_f32(&mut self, out: &mut [f32]) {
        for v in out {
            *v = self.next_f32();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix64_golden() {
        assert_eq!(mix64(0), 0x0);
        assert_eq!(mix64(1), 0x5692_161D_100B_05E5);
        assert_eq!(mix64(0xDEAD_BEEF), 0x4E06_2702_EC92_9EEA);
    }

    #[test]
    fn combine_golden() {
        assert_eq!(combine(1, 2), 0xF282_6F98_653E_9E57);
    }

    #[test]
    fn stream_golden() {
        let mut s = SplitMix64::new(42);
        assert_eq!(s.next_u64(), 0xBDD7_3226_2FEB_6E95);
        assert_eq!(s.next_u64(), 0x28EF_E333_B266_F103);
        assert_eq!(s.next_u64(), 0x4752_6757_130F_9F52);
    }

    #[test]
    fn f32_golden() {
        let mut s = SplitMix64::new(42);
        let got: Vec<f32> = (0..4).map(|_| s.next_f32()).collect();
        assert_eq!(
            got,
            vec![0.74156487, 0.15991038, 0.27860111, 0.34419066]
        );
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut s = SplitMix64::new(0xFFFF_FFFF_FFFF_FFFF);
        for _ in 0..10_000 {
            let v = s.next_f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn distinct_seeds_distinct_streams() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn mix64_injective_sample() {
        let mut seen = std::collections::HashSet::new();
        for i in 0..10_000u64 {
            assert!(seen.insert(mix64(i)));
        }
    }
}
