//! Synthetic 'structured blobs' dataset — exact mirror of
//! `python/compile/data.py` (see DESIGN.md §7).
//!
//! Class templates are split-independent; a sample blends its class
//! template with fresh noise (weak blend → FP ceiling ≈ 90%, giving
//! low-bit quantization a visible cliff). Seeds: train=1, calib=2, eval=3.

use super::rng::{combine, SplitMix64};

pub const TEMPLATE_TAG: u64 = 0x7E3A_17E5;
pub const SAMPLE_TAG: u64 = 0x5EED;

pub const TRAIN_SEED: u64 = 1;
pub const CALIB_SEED: u64 = 2;
pub const EVAL_SEED: u64 = 3;

#[derive(Debug, Clone, Copy)]
pub struct ImageShape {
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

impl ImageShape {
    pub fn len(&self) -> usize {
        self.h * self.w * self.c
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Deterministic per-class template (shared across all splits).
pub fn class_template(shape: ImageShape, k: usize) -> Vec<f32> {
    let mut rng = SplitMix64::new(combine(TEMPLATE_TAG, k as u64));
    let mut out = vec![0f32; shape.len()];
    rng.fill_f32(&mut out);
    out
}

/// One sample: (image in [0,1], label). `templates` is the stacked output
/// of [`class_template`] for k = 0..num_classes.
pub fn sample(
    shape: ImageShape,
    seed: u64,
    i: usize,
    num_classes: usize,
    templates: &[Vec<f32>],
) -> (Vec<f32>, i32) {
    let label = (i % num_classes) as i32;
    let mut rng = SplitMix64::new(combine(combine(seed, SAMPLE_TAG), i as u64));
    let alpha = 0.16 + 0.14 * rng.next_f32();
    let brightness = (rng.next_f32() - 0.5) * 0.2;
    let t = &templates[label as usize];
    let mut img = vec![0f32; shape.len()];
    // draw order matters: noise is a single contiguous fill, as in Python
    let mut noise = vec![0f32; shape.len()];
    rng.fill_f32(&mut noise);
    for j in 0..shape.len() {
        let v = alpha * t[j] + (1.0 - alpha) * noise[j] + brightness;
        img[j] = v.clamp(0.0, 1.0);
    }
    (img, label)
}

/// Generate `count` samples of split `seed`.
pub fn generate(
    shape: ImageShape,
    num_classes: usize,
    seed: u64,
    count: usize,
) -> (Vec<f32>, Vec<i32>) {
    let templates: Vec<Vec<f32>> =
        (0..num_classes).map(|k| class_template(shape, k)).collect();
    let mut images = Vec::with_capacity(count * shape.len());
    let mut labels = Vec::with_capacity(count);
    for i in 0..count {
        let (img, label) = sample(shape, seed, i, num_classes, &templates);
        images.extend_from_slice(&img);
        labels.push(label);
    }
    (images, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SHAPE: ImageShape = ImageShape { h: 16, w: 16, c: 3 };

    #[test]
    fn golden_matches_python() {
        // duplicated in python/tests/test_rng_data.py::test_golden_sample
        let (imgs, labels) = generate(SHAPE, 10, CALIB_SEED, 3);
        let expect = [
            0.5070157051086426,
            0.16118144989013672,
            0.40140822529792786,
            0.29602834582328796,
            0.2174665927886963,
        ];
        for (g, e) in imgs.iter().take(5).zip(expect.iter()) {
            assert!((f64::from(*g) - e).abs() < 1e-7, "{g} vs {e}");
        }
        assert_eq!(labels, vec![0, 1, 2]);
        let sum: f64 = imgs.iter().map(|v| f64::from(*v)).sum();
        assert!((sum - 1109.60693359375).abs() < 1e-2, "sum {sum}");
    }

    #[test]
    fn deterministic() {
        let a = generate(SHAPE, 10, 7, 4);
        let b = generate(SHAPE, 10, 7, 4);
        assert_eq!(a.0, b.0);
        assert_eq!(a.1, b.1);
    }

    #[test]
    fn templates_split_independent() {
        assert_eq!(class_template(SHAPE, 2), class_template(SHAPE, 2));
    }

    #[test]
    fn values_in_unit_interval() {
        let (imgs, _) = generate(SHAPE, 10, 5, 8);
        assert!(imgs.iter().all(|v| (0.0..=1.0).contains(v)));
    }

    #[test]
    fn labels_round_robin() {
        let (_, labels) = generate(SHAPE, 10, 5, 23);
        for (i, l) in labels.iter().enumerate() {
            assert_eq!(*l, (i % 10) as i32);
        }
    }

    #[test]
    fn splits_differ() {
        let (a, _) = generate(SHAPE, 10, CALIB_SEED, 2);
        let (b, _) = generate(SHAPE, 10, EVAL_SEED, 2);
        assert_ne!(a, b);
    }
}
