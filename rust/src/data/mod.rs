//! Deterministic data substrate: the splitmix64 RNG shared with the Python
//! build path, the synthetic 'structured blobs' dataset generator (exact
//! mirror of `python/compile/data.py`), and the DSET binary reader/writer.

pub mod rng;
pub mod store;
pub mod synthetic;

pub use rng::{combine, mix64, SplitMix64};
pub use store::Dataset;
