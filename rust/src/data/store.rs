//! DSET binary dataset reader/writer (mirror of `python/compile/data.py`
//! `save_dataset`/`load_dataset`).
//!
//! Layout: magic "DSET" | u32 count,h,w,c | f32 images | i32 labels.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::synthetic::ImageShape;

#[derive(Debug, Clone)]
pub struct Dataset {
    pub shape: ImageShape,
    pub count: usize,
    /// row-major [count, h, w, c]
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
}

impl Dataset {
    pub fn image(&self, i: usize) -> &[f32] {
        let n = self.shape.len();
        &self.images[i * n..(i + 1) * n]
    }

    /// Concatenate images `lo..hi` into one contiguous batch buffer.
    pub fn batch(&self, lo: usize, hi: usize) -> &[f32] {
        let n = self.shape.len();
        &self.images[lo * n..hi * n]
    }

    pub fn load(path: &Path) -> Result<Dataset> {
        let mut r = BufReader::new(
            File::open(path).with_context(|| format!("open {path:?}"))?,
        );
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != b"DSET" {
            bail!("bad DSET magic in {path:?}");
        }
        let count = read_u32(&mut r)? as usize;
        let h = read_u32(&mut r)? as usize;
        let w = read_u32(&mut r)? as usize;
        let c = read_u32(&mut r)? as usize;
        let shape = ImageShape { h, w, c };
        let mut images = vec![0f32; count * shape.len()];
        read_f32_into(&mut r, &mut images)?;
        let mut labels = vec![0i32; count];
        for l in labels.iter_mut() {
            let mut b = [0u8; 4];
            r.read_exact(&mut b)?;
            *l = i32::from_le_bytes(b);
        }
        if let Ok(md) = std::fs::metadata(path) {
            crate::obs::counter("io.read_bytes", md.len());
        }
        Ok(Dataset { shape, count, images, labels })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut w = BufWriter::new(File::create(path)?);
        w.write_all(b"DSET")?;
        for v in [
            self.count as u32,
            self.shape.h as u32,
            self.shape.w as u32,
            self.shape.c as u32,
        ] {
            w.write_all(&v.to_le_bytes())?;
        }
        for v in &self.images {
            w.write_all(&v.to_le_bytes())?;
        }
        for l in &self.labels {
            w.write_all(&l.to_le_bytes())?;
        }
        w.flush()?;
        if let Ok(md) = std::fs::metadata(path) {
            crate::obs::counter("io.write_bytes", md.len());
        }
        Ok(())
    }

    /// Heap footprint (f32 images + i32 labels), for the resident-bytes
    /// registry.
    pub fn resident_bytes(&self) -> u64 {
        (self.images.len() * 4 + self.labels.len() * 4) as u64
    }

    /// Take the first `n` samples (used for calibration-size ablations).
    pub fn take(&self, n: usize) -> Dataset {
        let n = n.min(self.count);
        Dataset {
            shape: self.shape,
            count: n,
            images: self.images[..n * self.shape.len()].to_vec(),
            labels: self.labels[..n].to_vec(),
        }
    }
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_f32_into<R: Read>(r: &mut R, out: &mut [f32]) -> Result<()> {
    let mut buf = vec![0u8; out.len() * 4];
    r.read_exact(&mut buf)?;
    for (i, v) in out.iter_mut().enumerate() {
        *v = f32::from_le_bytes(buf[i * 4..i * 4 + 4].try_into().unwrap());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::synthetic::{generate, ImageShape};
    use super::*;

    #[test]
    fn roundtrip() {
        let shape = ImageShape { h: 4, w: 4, c: 3 };
        let (images, labels) = generate(shape, 10, 2, 6);
        let ds = Dataset { shape, count: 6, images, labels };
        let dir = std::env::temp_dir().join("beacon_ptq_test_store");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("ds.bin");
        ds.save(&p).unwrap();
        let back = Dataset::load(&p).unwrap();
        assert_eq!(back.count, 6);
        assert_eq!(back.images, ds.images);
        assert_eq!(back.labels, ds.labels);
    }

    #[test]
    fn take_truncates() {
        let shape = ImageShape { h: 2, w: 2, c: 1 };
        let (images, labels) = generate(shape, 10, 2, 8);
        let ds = Dataset { shape, count: 8, images, labels };
        let t = ds.take(3);
        assert_eq!(t.count, 3);
        assert_eq!(t.images.len(), 3 * 4);
        assert_eq!(t.images[..], ds.images[..12]);
    }

    #[test]
    fn batch_slicing() {
        let shape = ImageShape { h: 2, w: 2, c: 1 };
        let (images, labels) = generate(shape, 10, 2, 5);
        let ds = Dataset { shape, count: 5, images, labels };
        assert_eq!(ds.batch(1, 3).len(), 2 * 4);
        assert_eq!(ds.batch(1, 3)[0], ds.image(1)[0]);
    }

    #[test]
    fn resident_bytes_counts_payloads() {
        let shape = ImageShape { h: 2, w: 2, c: 1 };
        let (images, labels) = generate(shape, 10, 2, 8);
        let ds = Dataset { shape, count: 8, images, labels };
        // 8 images × 4 px × 4 B + 8 labels × 4 B
        assert_eq!(ds.resident_bytes(), 8 * 4 * 4 + 8 * 4);
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("beacon_ptq_test_store2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, b"NOPE____").unwrap();
        assert!(Dataset::load(&p).is_err());
    }
}
