//! Zero-dependency observability: structured spans, counters and
//! mergeable log-bucket histograms behind one global recorder.
//!
//! Recording model (see docs/OBS.md for the full design note):
//!
//! * **Disabled is free.** Every entry point checks one relaxed
//!   `AtomicBool` and returns before touching thread-locals or
//!   allocating — the instrumented hot paths compile to a load+branch.
//! * **Enabled is lock-free on the hot path.** Events, counter deltas
//!   and histogram samples accumulate in per-thread buffers
//!   (`thread_local!`); a thread only takes the global mutex when its
//!   outermost span closes (the buffer drains in one append/merge) or
//!   when a counter fires outside any span (rare: store I/O).
//! * **Recording never perturbs results.** The scheduler's outputs are
//!   gathered in index order regardless of timing, and the recorder
//!   only observes — the traced run is bit-identical to the untraced
//!   one at any thread count (`rust/tests/obs_trace.rs` pins this).
//!
//! Consumers: [`trace::chrome_trace`] exports the Chrome trace-event
//! JSON behind `beacon --trace FILE` / `BEACON_TRACE`, and
//! [`report::MetricsReport`] condenses a snapshot into the metrics
//! section of a `QuantReport`.

pub mod hist;
pub mod memory;
pub mod report;
pub mod span;
pub mod trace;

pub use hist::{Hist, HistSummary};
pub use memory::{MemStats, MemoryReport, TrackingAlloc};
pub use report::MetricsReport;
pub use span::{SpanEvent, SpanGuard};

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use anyhow::{Context, Result};

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Total records (span events + counter deltas + histogram merges)
/// accepted since the last [`reset`] — the "disabled path records
/// nothing" tests key off this staying at zero.
static EVENTS_RECORDED: AtomicU64 = AtomicU64::new(0);

#[derive(Default)]
struct Store {
    events: Vec<SpanEvent>,
    counters: BTreeMap<String, u64>,
    hists: BTreeMap<String, Hist>,
}

fn global() -> &'static Mutex<Store> {
    static G: OnceLock<Mutex<Store>> = OnceLock::new();
    G.get_or_init(|| Mutex::new(Store::default()))
}

/// The single time origin every span timestamp is relative to,
/// initialized on first use (at [`enable`], in practice).
fn epoch() -> Instant {
    static E: OnceLock<Instant> = OnceLock::new();
    *E.get_or_init(Instant::now)
}

pub(crate) fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn recording on (idempotent). Pins the epoch so the first span's
/// timestamp is small.
pub fn enable() {
    let _ = epoch();
    ENABLED.store(true, Ordering::SeqCst);
}

pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Records accepted since the last [`reset`] (spans + counters +
/// histogram merges).
pub fn events_recorded() -> u64 {
    EVENTS_RECORDED.load(Ordering::SeqCst)
}

pub(crate) fn bump_recorded() {
    EVENTS_RECORDED.fetch_add(1, Ordering::Relaxed);
}

/// Drop everything recorded so far (global store + this thread's
/// buffer + the resident-bytes registry). Worker threads are scoped per
/// fan, so between runs the calling thread's buffer is the only live
/// one. Allocator counters are *not* reset — they are process-lifetime
/// monotone (use [`memory::reset_peak`] to re-arm the high-water mark).
pub fn reset() {
    span::reset_thread();
    memory::reset_registry();
    let mut g = global().lock().unwrap();
    *g = Store::default();
    EVENTS_RECORDED.store(0, Ordering::SeqCst);
}

/// Open a span with a static name. The guard records on drop; keep it
/// on the opening thread. `finish()` returns the elapsed seconds (the
/// pipeline's phase timers read it), measured whether or not the
/// recorder is on.
pub fn span(cat: &'static str, name: &'static str) -> SpanGuard {
    span::open(cat, || (name.to_string(), Vec::new()))
}

/// Open a span whose name/args are built lazily — `make` only runs when
/// the recorder is enabled, so a disabled span allocates nothing.
pub fn span_args<F>(cat: &'static str, make: F) -> SpanGuard
where
    F: FnOnce() -> (String, Vec<(&'static str, String)>),
{
    span::open(cat, make)
}

/// Add `delta` to the named counter. Inside a span the delta buffers
/// thread-locally; outside one it goes straight to the global store.
pub fn counter(name: &str, delta: u64) {
    if !enabled() || delta == 0 {
        return;
    }
    span::add_counter(name, delta);
}

/// Merge a locally accumulated histogram into the named global one
/// (the pool's per-worker item-latency histograms land here).
pub fn merge_hist(name: &str, h: Hist) {
    if !enabled() || h.total == 0 {
        return;
    }
    span::add_hist(name, h);
}

/// A coherent copy of everything recorded so far. Flushes the calling
/// thread's buffer first, so spans closed on this thread are visible
/// even while an outer span is still open.
pub fn snapshot() -> Snapshot {
    span::flush_thread();
    let resident = memory::resident_snapshot();
    let g = global().lock().unwrap();
    Snapshot {
        events: g.events.clone(),
        counters: g.counters.clone(),
        hists: g.hists.clone(),
        resident,
    }
}

/// Convenience: `true` when the `BEACON_TRACE` env var names a file.
pub fn trace_env() -> Option<String> {
    std::env::var("BEACON_TRACE").ok().filter(|v| !v.is_empty())
}

pub(crate) fn drain_into_global(
    events: &mut Vec<SpanEvent>,
    counters: &mut BTreeMap<String, u64>,
    hists: &mut BTreeMap<String, Hist>,
) {
    if events.is_empty() && counters.is_empty() && hists.is_empty() {
        return;
    }
    let mut g = global().lock().unwrap();
    g.events.append(events);
    for (k, v) in std::mem::take(counters) {
        *g.counters.entry(k).or_insert(0) += v;
    }
    for (k, h) in std::mem::take(hists) {
        g.hists.entry(k).or_insert_with(Hist::default).merge(&h);
    }
}

/// Everything the recorder collected, merged across threads.
#[derive(Debug, Clone, Default)]
pub struct Snapshot {
    pub events: Vec<SpanEvent>,
    pub counters: BTreeMap<String, u64>,
    pub hists: BTreeMap<String, Hist>,
    /// registered structural footprints ([`memory::set_resident`])
    pub resident: BTreeMap<String, u64>,
}

/// Write the current snapshot as Chrome trace-event JSON (open in
/// Perfetto or chrome://tracing).
pub fn write_chrome_trace(path: &Path) -> Result<()> {
    let snap = snapshot();
    std::fs::write(path, trace::render(&snap))
        .with_context(|| format!("write trace {path:?}"))?;
    Ok(())
}

/// Tests that toggle the global recorder (or the resident registry,
/// which [`reset`] clears) serialize on this lock so the rest of the
/// lib test binary never observes a half-enabled recorder. Shared with
/// the `memory` submodule's tests.
#[cfg(test)]
pub(crate) fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static L: OnceLock<Mutex<()>> = OnceLock::new();
    L.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lock() -> std::sync::MutexGuard<'static, ()> {
        test_lock()
    }

    #[test]
    fn disabled_records_nothing() {
        let _l = lock();
        reset();
        disable();
        {
            let _s = span("test", "outer");
            counter("test.count", 3);
            merge_hist("test.h", {
                let mut h = Hist::default();
                h.record(10);
                h
            });
        }
        assert_eq!(events_recorded(), 0);
        let snap = snapshot();
        assert!(snap.events.is_empty());
        assert!(snap.counters.is_empty());
        assert!(snap.hists.is_empty());
    }

    #[test]
    fn nested_spans_record_depth_and_args() {
        let _l = lock();
        reset();
        enable();
        {
            let _outer = span("test", "outer");
            {
                let _inner = span_args("test", || {
                    ("inner".to_string(), vec![("k", "v".to_string())])
                });
            }
            counter("test.count", 2);
            counter("test.count", 5);
        }
        disable();
        let snap = snapshot();
        assert_eq!(snap.events.len(), 2);
        // inner closes first, one level deeper than outer
        assert_eq!(snap.events[0].name, "inner");
        assert_eq!(snap.events[0].depth, 1);
        assert_eq!(snap.events[0].args, vec![("k", "v".to_string())]);
        assert_eq!(snap.events[1].name, "outer");
        assert_eq!(snap.events[1].depth, 0);
        assert_eq!(snap.events[0].tid, snap.events[1].tid);
        // inner lies within outer's window
        let (o, i) = (&snap.events[1], &snap.events[0]);
        assert!(i.start_ns >= o.start_ns);
        assert!(i.start_ns + i.dur_ns <= o.start_ns + o.dur_ns);
        assert_eq!(snap.counters.get("test.count"), Some(&7));
        assert!(events_recorded() >= 4);
        reset();
        assert_eq!(events_recorded(), 0);
        assert!(snapshot().events.is_empty());
    }

    #[test]
    fn finish_returns_elapsed_even_when_disabled() {
        let _l = lock();
        reset();
        disable();
        let s = span("test", "timed");
        std::thread::sleep(std::time::Duration::from_millis(2));
        let secs = s.finish();
        assert!(secs > 0.0);
        assert_eq!(events_recorded(), 0);
    }

    #[test]
    fn counter_outside_any_span_goes_global() {
        let _l = lock();
        reset();
        enable();
        counter("io.test_bytes", 123);
        disable();
        let snap = snapshot();
        assert_eq!(snap.counters.get("io.test_bytes"), Some(&123));
        reset();
    }
}
