//! Per-thread recording buffers and the RAII span guard.
//!
//! Each thread that records gets a `ThreadBuf` (thread-local): a small
//! `tid` handed out from a global counter (stable, dense — friendlier
//! than OS thread ids in a trace viewer), the current span depth, and
//! pending events/counters/histograms. Closing the outermost span
//! drains the buffer into the global store in one lock acquisition, so
//! worker threads never contend mid-work.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use super::hist::Hist;

/// One closed span, as stored and exported.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanEvent {
    pub name: String,
    pub cat: &'static str,
    /// Recorder-assigned thread id (1 = first recording thread).
    pub tid: u64,
    /// Nesting depth on the owning thread at open time (0 = outermost).
    pub depth: u32,
    pub start_ns: u64,
    pub dur_ns: u64,
    pub args: Vec<(&'static str, String)>,
    /// Heap live bytes sampled at span open/close and the process
    /// high-water mark at close, from [`super::memory`]. All zero when
    /// the tracking allocator is not installed.
    pub live_open_bytes: u64,
    pub live_close_bytes: u64,
    pub peak_close_bytes: u64,
}

static NEXT_TID: AtomicU64 = AtomicU64::new(1);

struct ThreadBuf {
    tid: u64,
    depth: u32,
    events: Vec<SpanEvent>,
    counters: BTreeMap<String, u64>,
    hists: BTreeMap<String, Hist>,
}

impl ThreadBuf {
    fn new() -> ThreadBuf {
        ThreadBuf {
            tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
            depth: 0,
            events: Vec::new(),
            counters: BTreeMap::new(),
            hists: BTreeMap::new(),
        }
    }

    fn drain(&mut self) {
        super::drain_into_global(&mut self.events, &mut self.counters, &mut self.hists);
    }
}

thread_local! {
    static BUF: RefCell<ThreadBuf> = RefCell::new(ThreadBuf::new());
}

/// What an enabled guard remembers about its open span.
struct RecOpen {
    name: String,
    cat: &'static str,
    start_ns: u64,
    args: Vec<(&'static str, String)>,
    live_open_bytes: u64,
}

/// RAII span guard: records a [`SpanEvent`] on drop when the recorder
/// was enabled at open time. Must be dropped on the thread that opened
/// it (it is `!Send` by construction — `RefCell` access is thread-local).
pub struct SpanGuard {
    start: Instant,
    rec: Option<RecOpen>,
    // Anchor the guard to its opening thread.
    _not_send: std::marker::PhantomData<*const ()>,
}

pub(super) fn open<F>(cat: &'static str, make: F) -> SpanGuard
where
    F: FnOnce() -> (String, Vec<(&'static str, String)>),
{
    let start = Instant::now();
    let rec = if super::enabled() {
        let (name, args) = make();
        let start_ns = super::now_ns();
        let live_open_bytes = super::memory::live_bytes();
        BUF.with(|b| b.borrow_mut().depth += 1);
        Some(RecOpen { name, cat, start_ns, args, live_open_bytes })
    } else {
        None
    };
    SpanGuard { start, rec, _not_send: std::marker::PhantomData }
}

impl SpanGuard {
    /// Close the span and return its elapsed wall time in seconds —
    /// the replacement for the pipeline's hand-rolled `Instant` timers.
    /// Valid (and allocation-free) whether or not recording is on.
    pub fn finish(self) -> f64 {
        let secs = self.start.elapsed().as_secs_f64();
        drop(self);
        secs
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(rec) = self.rec.take() else {
            return;
        };
        let end_ns = super::now_ns();
        let live_close_bytes = super::memory::live_bytes();
        let peak_close_bytes = super::memory::peak_bytes();
        BUF.with(|b| {
            let mut b = b.borrow_mut();
            b.depth = b.depth.saturating_sub(1);
            let depth = b.depth;
            let tid = b.tid;
            b.events.push(SpanEvent {
                name: rec.name,
                cat: rec.cat,
                tid,
                depth,
                start_ns: rec.start_ns,
                dur_ns: end_ns.saturating_sub(rec.start_ns),
                args: rec.args,
                live_open_bytes: rec.live_open_bytes,
                live_close_bytes,
                peak_close_bytes,
            });
            super::bump_recorded();
            if depth == 0 {
                b.drain();
            }
        });
    }
}

/// Buffer a counter delta; flushes immediately when outside any span
/// (e.g. store I/O on the main thread between phases).
pub(super) fn add_counter(name: &str, delta: u64) {
    BUF.with(|b| {
        let mut b = b.borrow_mut();
        *b.counters.entry(name.to_string()).or_insert(0) += delta;
        super::bump_recorded();
        if b.depth == 0 {
            b.drain();
        }
    });
}

/// Buffer a histogram merge; same flush rule as [`add_counter`].
pub(super) fn add_hist(name: &str, h: Hist) {
    BUF.with(|b| {
        let mut b = b.borrow_mut();
        b.hists.entry(name.to_string()).or_insert_with(Hist::default).merge(&h);
        super::bump_recorded();
        if b.depth == 0 {
            b.drain();
        }
    });
}

/// Push this thread's buffered records to the global store (snapshot
/// support: see [`super::snapshot`]).
pub(super) fn flush_thread() {
    BUF.with(|b| b.borrow_mut().drain());
}

/// Clear this thread's buffer without publishing it (reset support).
pub(super) fn reset_thread() {
    BUF.with(|b| {
        let mut b = b.borrow_mut();
        b.depth = 0;
        b.events.clear();
        b.counters.clear();
        b.hists.clear();
    });
}
