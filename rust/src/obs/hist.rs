//! Fixed log-bucket histogram: 64 power-of-two buckets covering the
//! full `u64` range, mergeable across threads with plain addition.
//!
//! Value `v` lands in bucket `0` when `v == 0`, otherwise in bucket
//! `64 - v.leading_zeros()` clamped to 63 — i.e. bucket `b >= 1` holds
//! `[2^(b-1), 2^b)`. Percentiles report the bucket midpoint
//! (`1.5 * 2^(b-1)`), which is within ±50% of the true value: plenty
//! for "p99 per-channel ns" style summaries and entirely allocation-
//! and float-free on the record path.

/// Mergeable log-bucket histogram of `u64` samples (typically ns).
///
/// Besides the bucketed quantiles, the exact `min`/`max` ride along:
/// unlike the percentiles they survive merging without bucket error
/// (min of mins, max of maxes).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Hist {
    pub counts: [u64; 64],
    pub total: u64,
    pub sum: u64,
    /// exact smallest sample (`u64::MAX` while empty)
    pub min: u64,
    /// exact largest sample (0 while empty)
    pub max: u64,
}

impl Default for Hist {
    fn default() -> Hist {
        Hist { counts: [0u64; 64], total: 0, sum: 0, min: u64::MAX, max: 0 }
    }
}

fn bucket(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        ((64 - v.leading_zeros()) as usize).min(63)
    }
}

/// Representative (midpoint) value for a bucket index.
fn bucket_rep(b: usize) -> u64 {
    if b == 0 {
        0
    } else {
        let lo = 1u64 << (b - 1);
        lo + lo / 2
    }
}

impl Hist {
    pub fn record(&mut self, v: u64) {
        self.counts[bucket(v)] += 1;
        self.total += 1;
        self.sum = self.sum.saturating_add(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn merge(&mut self, other: &Hist) {
        for (c, o) in self.counts.iter_mut().zip(other.counts.iter()) {
            *c += o;
        }
        self.total += other.total;
        self.sum = self.sum.saturating_add(other.sum);
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Approximate percentile (`q` in [0, 1]) as the midpoint of the
    /// bucket containing the q-th sample. Returns 0 on an empty hist.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut seen = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_rep(b);
            }
        }
        bucket_rep(63)
    }

    pub fn mean(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.sum / self.total
        }
    }

    pub fn summary(&self) -> HistSummary {
        HistSummary {
            count: self.total,
            p50: self.percentile(0.50),
            p95: self.percentile(0.95),
            p99: self.percentile(0.99),
            mean: self.mean(),
            min: if self.total == 0 { 0 } else { self.min },
            max: self.max,
        }
    }
}

/// Condensed histogram stats for reports and bench rows. `min`/`max`
/// are exact (merge-stable); the quantiles are bucket midpoints.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HistSummary {
    pub count: u64,
    pub p50: u64,
    pub p95: u64,
    pub p99: u64,
    pub mean: u64,
    pub min: u64,
    pub max: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_powers_of_two() {
        assert_eq!(bucket(0), 0);
        assert_eq!(bucket(1), 1);
        assert_eq!(bucket(2), 2);
        assert_eq!(bucket(3), 2);
        assert_eq!(bucket(4), 3);
        assert_eq!(bucket(1023), 10);
        assert_eq!(bucket(1024), 11);
        assert_eq!(bucket(u64::MAX), 63);
    }

    #[test]
    fn percentiles_track_distribution() {
        let mut h = Hist::default();
        for _ in 0..99 {
            h.record(100); // bucket 7: [64, 128)
        }
        h.record(1 << 20); // one outlier
        assert_eq!(h.total, 100);
        assert_eq!(h.percentile(0.50), bucket_rep(7));
        assert_eq!(h.percentile(0.95), bucket_rep(7));
        // p99 rank = 99 -> still the common bucket; p100 hits the outlier
        assert_eq!(h.percentile(0.99), bucket_rep(7));
        assert_eq!(h.percentile(1.0), bucket_rep(21));
        assert!(h.mean() > 100);
        // min/max are exact, not bucket midpoints
        assert_eq!(h.summary().min, 100);
        assert_eq!(h.summary().max, 1 << 20);
    }

    #[test]
    fn merge_equals_combined_recording() {
        let mut a = Hist::default();
        let mut b = Hist::default();
        let mut both = Hist::default();
        for v in [3u64, 17, 400, 0, 65_000] {
            a.record(v);
            both.record(v);
        }
        for v in [5u64, 900, 12] {
            b.record(v);
            both.record(v);
        }
        a.merge(&b);
        assert_eq!(a, both);
        assert_eq!(a.summary(), both.summary());
        assert_eq!(a.summary().min, 0);
        assert_eq!(a.summary().max, 65_000);
        // merging an empty hist is the identity (min stays u64::MAX
        // internally but never leaks into a summary)
        let mut c = both.clone();
        c.merge(&Hist::default());
        assert_eq!(c.summary(), both.summary());
    }

    #[test]
    fn empty_hist_summary_is_zero() {
        let s = Hist::default().summary();
        assert_eq!(s.count, 0);
        assert_eq!(s.p50, 0);
        assert_eq!(s.p99, 0);
        assert_eq!(s.mean, 0);
        assert_eq!(s.min, 0);
        assert_eq!(s.max, 0);
    }
}
