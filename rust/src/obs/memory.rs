//! Memory observability: a tracking global allocator, a resident-bytes
//! registry for the big structural buffers, and the `MemoryReport`
//! section of a `QuantReport`.
//!
//! [`TrackingAlloc`] wraps `std::alloc::System` and keeps live/peak
//! byte counts plus alloc/dealloc totals in relaxed atomics — a few ns
//! per allocation, no locks, no allocation of its own. Binaries opt in
//! with `#[global_allocator]` (the `beacon` CLI, the kernel bench, the
//! serving example and the memory test suite all do); with the system
//! allocator the counters simply stay at zero and every consumer
//! reports "untracked" instead of wrong numbers.
//!
//! Peak tracking uses `fetch_max` on the post-increment live count.
//! Relaxed ordering is safe here because the counters are monotone
//! *summaries*, not synchronization: every `fetch_add`/`fetch_max` is
//! individually atomic, so no update is lost — the only slack is that a
//! reader racing an in-flight allocation on another thread can observe
//! the `LIVE` bump before the matching `PEAK` max lands. The high-water
//! mark is exact once the racing allocation's `fetch_max` completes,
//! which is what phase close-out and end-of-run reporting read.

use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use super::Snapshot;

static LIVE: AtomicU64 = AtomicU64::new(0);
static PEAK: AtomicU64 = AtomicU64::new(0);
static ALLOCS: AtomicU64 = AtomicU64::new(0);
static DEALLOCS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);
static FREED_BYTES: AtomicU64 = AtomicU64::new(0);

/// Heap-tracking allocator delegating to [`System`]. Install with
/// `#[global_allocator] static A: TrackingAlloc = TrackingAlloc;`.
pub struct TrackingAlloc;

#[inline]
fn on_alloc(size: usize) {
    ALLOCS.fetch_add(1, Ordering::Relaxed);
    ALLOC_BYTES.fetch_add(size as u64, Ordering::Relaxed);
    let live = LIVE.fetch_add(size as u64, Ordering::Relaxed) + size as u64;
    PEAK.fetch_max(live, Ordering::Relaxed);
}

#[inline]
fn on_dealloc(size: usize) {
    DEALLOCS.fetch_add(1, Ordering::Relaxed);
    FREED_BYTES.fetch_add(size as u64, Ordering::Relaxed);
    LIVE.fetch_sub(size as u64, Ordering::Relaxed);
}

unsafe impl GlobalAlloc for TrackingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            on_alloc(layout.size());
        }
        p
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc_zeroed(layout);
        if !p.is_null() {
            on_alloc(layout.size());
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        on_dealloc(layout.size());
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            on_dealloc(layout.size());
            on_alloc(new_size);
        }
        p
    }
}

/// Point-in-time allocator counters (all zero when [`TrackingAlloc`] is
/// not the process allocator).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct MemStats {
    pub live_bytes: u64,
    pub peak_bytes: u64,
    pub allocs: u64,
    pub deallocs: u64,
    pub alloc_bytes: u64,
    pub freed_bytes: u64,
}

pub fn stats() -> MemStats {
    MemStats {
        live_bytes: LIVE.load(Ordering::Relaxed),
        peak_bytes: PEAK.load(Ordering::Relaxed),
        allocs: ALLOCS.load(Ordering::Relaxed),
        deallocs: DEALLOCS.load(Ordering::Relaxed),
        alloc_bytes: ALLOC_BYTES.load(Ordering::Relaxed),
        freed_bytes: FREED_BYTES.load(Ordering::Relaxed),
    }
}

pub fn live_bytes() -> u64 {
    LIVE.load(Ordering::Relaxed)
}

pub fn peak_bytes() -> u64 {
    PEAK.load(Ordering::Relaxed)
}

/// `true` when [`TrackingAlloc`] is installed as the global allocator —
/// detected by the alloc counter being nonzero, which any running Rust
/// program long since guarantees (argv/env/runtime setup all allocate).
pub fn tracking() -> bool {
    ALLOCS.load(Ordering::Relaxed) > 0
}

/// Restart the high-water mark from the current live count, returning
/// that count — the bench uses this to measure per-section peaks.
pub fn reset_peak() -> u64 {
    let live = LIVE.load(Ordering::Relaxed);
    PEAK.store(live, Ordering::Relaxed);
    live
}

/// Resident-bytes registry: the gram cache, weight/data stores and
/// packed channels publish their *structural* footprint here under a
/// stable name (last write per name wins). Unlike the allocator
/// counters this is opt-in per data structure, so the report can say
/// "the gram cache is 38 MiB of the 90 MiB peak".
fn registry() -> &'static Mutex<BTreeMap<String, u64>> {
    static R: OnceLock<Mutex<BTreeMap<String, u64>>> = OnceLock::new();
    R.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Publish (or refresh) a named structure's resident byte count. Cheap
/// and rare (once per cache build / store load), so it is not gated on
/// the recorder being enabled — footprints registered before
/// `obs::enable()` still show up in the report.
pub fn set_resident(name: &str, bytes: u64) {
    registry().lock().unwrap().insert(name.to_string(), bytes);
}

pub(crate) fn resident_snapshot() -> BTreeMap<String, u64> {
    registry().lock().unwrap().clone()
}

pub(crate) fn reset_registry() {
    registry().lock().unwrap().clear();
}

/// Per-phase heap movement, read off the phase span's open/close
/// live-byte samples.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseMem {
    pub name: String,
    /// live-bytes delta across the phase (negative = net free)
    pub net_bytes: i64,
    /// process high-water mark observed at phase close
    pub peak_bytes: u64,
}

/// Packed-weights footprint vs the f32 weights they replace — the
/// paper's storage-model claim, measured on the actual codes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PackedFootprint {
    /// bit-stream payload: Σ ceil(len·storage_bits / 8) over channels
    pub payload_bytes: u64,
    /// per-channel metadata (scale + offset f32s)
    pub meta_bytes: u64,
    /// the f32 weights being replaced: Σ numel · 4
    pub fp_bytes: u64,
    /// Σ numel·storage_bits / Σ numel·32 — what the payload ratio must
    /// track (ceil-rounding per channel is the only slack)
    pub theoretical_ratio: f64,
}

impl PackedFootprint {
    /// Measured payload-over-f32 ratio (metadata reported separately:
    /// scale/offset bytes are per-channel constants, not per-weight).
    pub fn ratio(&self) -> f64 {
        if self.fp_bytes == 0 {
            return 0.0;
        }
        self.payload_bytes as f64 / self.fp_bytes as f64
    }

    /// Relative deviation of the measured ratio from the theoretical
    /// bits ratio — the memory-footprint assertion checks this ≤ 10%.
    pub fn ratio_error(&self) -> f64 {
        if self.theoretical_ratio == 0.0 {
            return 0.0;
        }
        (self.ratio() / self.theoretical_ratio - 1.0).abs()
    }
}

/// The memory section of a `QuantReport`: allocator totals, per-phase
/// heap deltas, registered resident footprints and the packed ratio.
#[derive(Debug, Clone, PartialEq)]
pub struct MemoryReport {
    /// whether [`TrackingAlloc`] is installed (false ⇒ stats are zero)
    pub tracking: bool,
    pub stats: MemStats,
    /// one row per closed `cat == "phase"` span, in close order
    pub phases: Vec<PhaseMem>,
    /// registered structural footprints, name-sorted
    pub resident: Vec<(String, u64)>,
    pub packed: Option<PackedFootprint>,
}

impl MemoryReport {
    /// Build from a snapshot (phase spans carry the live-byte samples)
    /// plus the pipeline's packed-footprint measurement.
    pub fn from_snapshot(snap: &Snapshot, packed: Option<PackedFootprint>) -> MemoryReport {
        let phases = snap
            .events
            .iter()
            .filter(|e| e.cat == "phase")
            .map(|e| PhaseMem {
                name: e.name.clone(),
                net_bytes: e.live_close_bytes as i64 - e.live_open_bytes as i64,
                peak_bytes: e.peak_close_bytes,
            })
            .collect();
        MemoryReport {
            tracking: tracking(),
            stats: stats(),
            phases,
            resident: snap.resident.iter().map(|(k, v)| (k.clone(), *v)).collect(),
            packed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::SpanEvent;

    #[test]
    fn resident_registry_roundtrip() {
        let _l = crate::obs::test_lock();
        reset_registry();
        set_resident("test.gram_cache", 1024);
        set_resident("test.weights", 2048);
        set_resident("test.gram_cache", 4096); // last write wins
        let snap = resident_snapshot();
        assert_eq!(snap.get("test.gram_cache"), Some(&4096));
        assert_eq!(snap.get("test.weights"), Some(&2048));
        reset_registry();
        assert!(resident_snapshot().is_empty());
    }

    #[test]
    fn packed_footprint_ratio_math() {
        // 4096 weights at 2-bit: payload 1024 B vs 16384 B of f32
        let pf = PackedFootprint {
            payload_bytes: 1024,
            meta_bytes: 8,
            fp_bytes: 16384,
            theoretical_ratio: 2.0 / 32.0,
        };
        assert!((pf.ratio() - 0.0625).abs() < 1e-12);
        assert!(pf.ratio_error() < 1e-12);
        let empty = PackedFootprint {
            payload_bytes: 0,
            meta_bytes: 0,
            fp_bytes: 0,
            theoretical_ratio: 0.0,
        };
        assert_eq!(empty.ratio(), 0.0);
        assert_eq!(empty.ratio_error(), 0.0);
    }

    #[test]
    fn report_extracts_phase_deltas_from_spans() {
        let mut snap = Snapshot::default();
        snap.events.push(SpanEvent {
            name: "phase.quantize".to_string(),
            cat: "phase",
            tid: 1,
            depth: 0,
            start_ns: 0,
            dur_ns: 1_000,
            args: Vec::new(),
            live_open_bytes: 1_000,
            live_close_bytes: 5_000,
            peak_close_bytes: 9_000,
        });
        snap.events.push(SpanEvent {
            name: "phase.eval".to_string(),
            cat: "phase",
            tid: 1,
            depth: 0,
            start_ns: 2_000,
            dur_ns: 500,
            args: Vec::new(),
            live_open_bytes: 5_000,
            live_close_bytes: 3_000,
            peak_close_bytes: 9_500,
        });
        // non-phase spans are ignored
        snap.events.push(SpanEvent {
            name: "layer[0]".to_string(),
            cat: "engine",
            tid: 2,
            depth: 1,
            start_ns: 10,
            dur_ns: 10,
            args: Vec::new(),
            live_open_bytes: 7,
            live_close_bytes: 7,
            peak_close_bytes: 7,
        });
        snap.resident.insert("pipeline.gram_cache".to_string(), 777);
        let r = MemoryReport::from_snapshot(&snap, None);
        assert_eq!(r.phases.len(), 2);
        assert_eq!(r.phases[0].name, "phase.quantize");
        assert_eq!(r.phases[0].net_bytes, 4_000);
        assert_eq!(r.phases[0].peak_bytes, 9_000);
        assert_eq!(r.phases[1].net_bytes, -2_000);
        assert_eq!(r.resident, vec![("pipeline.gram_cache".to_string(), 777)]);
        assert!(r.packed.is_none());
    }
}
