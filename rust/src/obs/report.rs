//! Condensed metrics derived from a recorder [`Snapshot`] — the
//! `metrics` section of a `QuantReport` and the extra columns in
//! `BENCH_quant.json` rows.

use super::hist::HistSummary;
use super::Snapshot;

/// Macro-level run metrics: per-phase wall time, scheduler worker
/// utilization, gram-cache hit rate, store I/O volume and the
/// per-channel latency distribution.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsReport {
    /// `(phase name, seconds)` in execution order, as handed in by the
    /// pipeline (span timings survive even when the recorder is off).
    pub phases: Vec<(String, f64)>,
    /// Busy fraction of the worker pool inside the `phase.quantize`
    /// window: sum of worker-span time / (window × distinct workers).
    pub worker_utilization: Option<f64>,
    /// Distinct `pool.worker` spans' thread ids seen in that window.
    pub workers: usize,
    pub gram_cache_hits: u64,
    pub gram_cache_misses: u64,
    pub io_read_bytes: u64,
    pub io_write_bytes: u64,
    /// Summary of `engine.channels.item_ns` (per-channel quantize ns).
    pub channel_ns: Option<HistSummary>,
    /// Distinct recorder thread ids across the whole snapshot.
    pub threads_seen: usize,
}

impl MetricsReport {
    /// Build from a snapshot plus the pipeline's phase timings.
    pub fn from_snapshot(snap: &Snapshot, phases: Vec<(String, f64)>) -> MetricsReport {
        let window = snap
            .events
            .iter()
            .find(|e| e.name == "phase.quantize")
            .map(|e| (e.start_ns, e.start_ns + e.dur_ns));
        let mut worker_tids: Vec<u64> = Vec::new();
        let mut busy_ns = 0u64;
        if let Some((lo, hi)) = window {
            for e in &snap.events {
                if e.cat == "pool.worker" && e.start_ns >= lo && e.start_ns < hi {
                    busy_ns += e.dur_ns;
                    if !worker_tids.contains(&e.tid) {
                        worker_tids.push(e.tid);
                    }
                }
            }
        }
        let worker_utilization = match window {
            Some((lo, hi)) if !worker_tids.is_empty() && hi > lo => {
                let capacity = (hi - lo) as f64 * worker_tids.len() as f64;
                Some((busy_ns as f64 / capacity).min(1.0))
            }
            _ => None,
        };
        let mut tids: Vec<u64> = Vec::new();
        for e in &snap.events {
            if !tids.contains(&e.tid) {
                tids.push(e.tid);
            }
        }
        let counter = |name: &str| snap.counters.get(name).copied().unwrap_or(0);
        MetricsReport {
            phases,
            worker_utilization,
            workers: worker_tids.len(),
            gram_cache_hits: counter("pipeline.gram_cache.hit"),
            gram_cache_misses: counter("pipeline.gram_cache.miss"),
            io_read_bytes: counter("io.read_bytes"),
            io_write_bytes: counter("io.write_bytes"),
            channel_ns: snap.hists.get("engine.channels.item_ns").map(|h| h.summary()),
            threads_seen: tids.len(),
        }
    }

    /// Gram-cache hit rate in [0, 1]; `None` when the cache was never
    /// consulted.
    pub fn gram_cache_hit_rate(&self) -> Option<f64> {
        let total = self.gram_cache_hits + self.gram_cache_misses;
        if total == 0 {
            None
        } else {
            Some(self.gram_cache_hits as f64 / total as f64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::SpanEvent;

    fn span(name: &str, cat: &'static str, tid: u64, start_ns: u64, dur_ns: u64) -> SpanEvent {
        SpanEvent {
            name: name.to_string(),
            cat,
            tid,
            depth: 0,
            start_ns,
            dur_ns,
            args: Vec::new(),
            live_open_bytes: 0,
            live_close_bytes: 0,
            peak_close_bytes: 0,
        }
    }

    #[test]
    fn utilization_counts_workers_in_quantize_window() {
        let mut snap = Snapshot::default();
        snap.events.push(span("phase.quantize", "phase", 1, 0, 1_000));
        snap.events.push(span("engine.layers.worker", "pool.worker", 2, 0, 800));
        snap.events.push(span("engine.layers.worker", "pool.worker", 3, 0, 600));
        // outside the window: ignored
        snap.events.push(span("engine.layers.worker", "pool.worker", 4, 5_000, 100));
        let m = MetricsReport::from_snapshot(&snap, vec![("quantize".to_string(), 1e-6)]);
        assert_eq!(m.workers, 2);
        let u = m.worker_utilization.unwrap();
        assert!((u - 0.7).abs() < 1e-9, "got {u}");
        assert_eq!(m.threads_seen, 4);
    }

    #[test]
    fn no_quantize_phase_means_no_utilization() {
        let mut snap = Snapshot::default();
        snap.events.push(span("phase.eval", "phase", 1, 0, 1_000));
        let m = MetricsReport::from_snapshot(&snap, Vec::new());
        assert!(m.worker_utilization.is_none());
        assert_eq!(m.workers, 0);
    }

    #[test]
    fn cache_hit_rate() {
        let mut snap = Snapshot::default();
        snap.counters.insert("pipeline.gram_cache.hit".to_string(), 3);
        snap.counters.insert("pipeline.gram_cache.miss".to_string(), 1);
        let m = MetricsReport::from_snapshot(&snap, Vec::new());
        assert_eq!(m.gram_cache_hit_rate(), Some(0.75));
        let empty = MetricsReport::from_snapshot(&Snapshot::default(), Vec::new());
        assert_eq!(empty.gram_cache_hit_rate(), None);
    }
}
