//! Chrome trace-event JSON export (the "JSON Array Format with
//! metadata" flavor: a top-level object with a `traceEvents` array).
//! Load the output in Perfetto (ui.perfetto.dev) or `chrome://tracing`.
//!
//! Each closed span becomes one complete event (`"ph": "X"`) with
//! microsecond `ts`/`dur` relative to the recorder epoch; the viewer
//! reconstructs nesting per track from time containment, which matches
//! the recorder's per-thread depth exactly. Counters and histogram
//! summaries ride along as top-level metadata objects so one file
//! carries the whole snapshot.

use std::collections::BTreeMap;

use super::Snapshot;
use crate::util::json::Value;

fn obj(pairs: Vec<(&str, Value)>) -> Value {
    let mut m = BTreeMap::new();
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    Value::Obj(m)
}

fn num(n: u64) -> Value {
    Value::Num(n as f64)
}

fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

/// Perfetto counter event (`"ph": "C"`): one named numeric sample; the
/// viewer draws the series as a track next to the span rows.
fn counter_event(name: &str, ts_ns: u64, bytes: u64) -> Value {
    obj(vec![
        ("name", s(name)),
        ("cat", s("memory")),
        ("ph", s("C")),
        ("pid", num(1)),
        ("tid", num(0)),
        ("ts", num(ts_ns / 1_000)),
        ("args", obj(vec![("bytes", num(bytes))])),
    ])
}

/// Build the trace document as a [`Value`] tree.
pub fn chrome_trace(snap: &Snapshot) -> Value {
    let mut events: Vec<Value> = Vec::with_capacity(snap.events.len() + 2);
    events.push(obj(vec![
        ("name", s("process_name")),
        ("ph", s("M")),
        ("pid", num(1)),
        ("tid", num(0)),
        ("args", obj(vec![("name", s("beacon"))])),
    ]));
    for ev in &snap.events {
        let mut args: Vec<(&str, Value)> = vec![("depth", num(ev.depth as u64))];
        for (k, v) in &ev.args {
            args.push((*k, s(v)));
        }
        events.push(obj(vec![
            ("name", s(&ev.name)),
            ("cat", s(ev.cat)),
            ("ph", s("X")),
            ("pid", num(1)),
            ("tid", num(ev.tid)),
            ("ts", num(ev.start_ns / 1_000)),
            ("dur", num((ev.dur_ns / 1_000).max(1))),
            ("args", obj(args)),
        ]));
    }
    // Heap timeline from the spans' live-byte samples (tracking
    // allocator installed ⇒ nonzero). Two points per span — open and
    // close — time-sorted into one "heap.live_bytes" counter track,
    // plus the high-water mark at each close.
    let mut live: Vec<(u64, u64)> = Vec::new();
    let mut peak: Vec<(u64, u64)> = Vec::new();
    for ev in &snap.events {
        if ev.live_open_bytes == 0 && ev.live_close_bytes == 0 {
            continue;
        }
        live.push((ev.start_ns, ev.live_open_bytes));
        live.push((ev.start_ns + ev.dur_ns, ev.live_close_bytes));
        peak.push((ev.start_ns + ev.dur_ns, ev.peak_close_bytes));
    }
    live.sort_unstable();
    peak.sort_unstable();
    for (ts, bytes) in live {
        events.push(counter_event("heap.live_bytes", ts, bytes));
    }
    for (ts, bytes) in peak {
        events.push(counter_event("heap.peak_bytes", ts, bytes));
    }
    let counters = obj(
        snap.counters
            .iter()
            .map(|(k, v)| (k.as_str(), num(*v)))
            .collect(),
    );
    let hists = obj(
        snap.hists
            .iter()
            .map(|(k, h)| {
                let sm = h.summary();
                (
                    k.as_str(),
                    obj(vec![
                        ("count", num(sm.count)),
                        ("p50", num(sm.p50)),
                        ("p95", num(sm.p95)),
                        ("p99", num(sm.p99)),
                        ("mean", num(sm.mean)),
                        ("min", num(sm.min)),
                        ("max", num(sm.max)),
                    ]),
                )
            })
            .collect(),
    );
    let resident = obj(
        snap.resident
            .iter()
            .map(|(k, v)| (k.as_str(), num(*v)))
            .collect(),
    );
    obj(vec![
        ("traceEvents", Value::Arr(events)),
        ("displayTimeUnit", s("ms")),
        ("beaconCounters", counters),
        ("beaconHistograms", hists),
        ("beaconResident", resident),
    ])
}

/// Render the trace document to a JSON string.
pub fn render(snap: &Snapshot) -> String {
    chrome_trace(snap).to_json()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::SpanEvent;

    fn sample_snapshot() -> Snapshot {
        let mut snap = Snapshot::default();
        snap.events.push(SpanEvent {
            name: "phase.quantize".to_string(),
            cat: "phase",
            tid: 1,
            depth: 0,
            start_ns: 5_000,
            dur_ns: 2_000_000,
            args: vec![("layers", "3".to_string())],
            live_open_bytes: 0,
            live_close_bytes: 0,
            peak_close_bytes: 0,
        });
        snap.events.push(SpanEvent {
            name: "layer[0]".to_string(),
            cat: "engine",
            tid: 2,
            depth: 1,
            start_ns: 10_000,
            dur_ns: 500_000,
            args: Vec::new(),
            live_open_bytes: 0,
            live_close_bytes: 0,
            peak_close_bytes: 0,
        });
        snap.counters.insert("pipeline.gram_cache.hit".to_string(), 4);
        let mut h = crate::obs::Hist::default();
        h.record(900);
        h.record(1_100);
        snap.hists.insert("engine.channels.item_ns".to_string(), h);
        snap
    }

    #[test]
    fn trace_is_valid_json_with_expected_shape() {
        let snap = sample_snapshot();
        let text = render(&snap);
        let v = Value::parse(&text).expect("trace must be valid JSON");
        let evs = v.at(&["traceEvents"]).as_arr().unwrap();
        // metadata event + 2 spans
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].at(&["ph"]).as_str(), Some("M"));
        let span = &evs[1];
        assert_eq!(span.at(&["name"]).as_str(), Some("phase.quantize"));
        assert_eq!(span.at(&["ph"]).as_str(), Some("X"));
        assert_eq!(span.at(&["ts"]).as_f64(), Some(5.0));
        assert_eq!(span.at(&["dur"]).as_f64(), Some(2_000.0));
        assert_eq!(span.at(&["args", "layers"]).as_str(), Some("3"));
        assert_eq!(evs[2].at(&["tid"]).as_f64(), Some(2.0));
        assert_eq!(
            v.at(&["beaconCounters", "pipeline.gram_cache.hit"]).as_f64(),
            Some(4.0)
        );
        let hist = v.at(&["beaconHistograms", "engine.channels.item_ns"]);
        assert_eq!(hist.at(&["count"]).as_f64(), Some(2.0));
    }

    #[test]
    fn sub_microsecond_spans_keep_nonzero_duration() {
        let mut snap = Snapshot::default();
        snap.events.push(SpanEvent {
            name: "tiny".to_string(),
            cat: "test",
            tid: 1,
            depth: 0,
            start_ns: 100,
            dur_ns: 200,
            args: Vec::new(),
            live_open_bytes: 0,
            live_close_bytes: 0,
            peak_close_bytes: 0,
        });
        let v = chrome_trace(&snap);
        let evs = v.at(&["traceEvents"]).as_arr().unwrap();
        assert_eq!(evs[1].at(&["dur"]).as_f64(), Some(1.0));
    }

    #[test]
    fn heap_counter_events_emitted_for_mem_samples() {
        let mut snap = Snapshot::default();
        snap.events.push(SpanEvent {
            name: "phase.quantize".to_string(),
            cat: "phase",
            tid: 1,
            depth: 0,
            start_ns: 10_000,
            dur_ns: 30_000,
            args: Vec::new(),
            live_open_bytes: 1_000_000,
            live_close_bytes: 3_000_000,
            peak_close_bytes: 5_000_000,
        });
        snap.resident.insert("pipeline.gram_cache".to_string(), 4_096);
        let v = chrome_trace(&snap);
        let evs = v.at(&["traceEvents"]).as_arr().unwrap();
        // metadata + span + 2 live samples + 1 peak sample
        assert_eq!(evs.len(), 5);
        let cs: Vec<_> = evs
            .iter()
            .filter(|e| e.at(&["ph"]).as_str() == Some("C"))
            .collect();
        assert_eq!(cs.len(), 3);
        assert_eq!(cs[0].at(&["name"]).as_str(), Some("heap.live_bytes"));
        assert_eq!(cs[0].at(&["ts"]).as_f64(), Some(10.0));
        assert_eq!(cs[0].at(&["args", "bytes"]).as_f64(), Some(1_000_000.0));
        assert_eq!(cs[1].at(&["ts"]).as_f64(), Some(40.0));
        assert_eq!(cs[1].at(&["args", "bytes"]).as_f64(), Some(3_000_000.0));
        assert_eq!(cs[2].at(&["name"]).as_str(), Some("heap.peak_bytes"));
        assert_eq!(cs[2].at(&["args", "bytes"]).as_f64(), Some(5_000_000.0));
        assert_eq!(
            v.at(&["beaconResident", "pipeline.gram_cache"]).as_f64(),
            Some(4_096.0)
        );
    }

    #[test]
    fn zero_mem_spans_emit_no_counter_events() {
        // system allocator (all samples zero): the heap track is absent
        let v = chrome_trace(&sample_snapshot());
        let evs = v.at(&["traceEvents"]).as_arr().unwrap();
        assert!(evs.iter().all(|e| e.at(&["ph"]).as_str() != Some("C")));
    }
}
