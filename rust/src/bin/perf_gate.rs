//! `perf_gate` — the CI perf-regression gate over the machine-readable
//! kernel perf records.
//!
//! `cargo bench --bench quant_kernels` writes `BENCH_quant.json`
//! (`method × bits × threads → ns/channel`) and `BENCH_memory.json`
//! (same grid → peak heap bytes per layer quantize, via the tracking
//! allocator); this binary diffs each against its committed baseline
//! (`BENCH_baseline.json` / `BENCH_memory_baseline.json`) and **fails
//! (exit 1) when any matching row regresses by more than the tolerance**
//! (default 25%, `--tolerance-pct` / `PERF_GATE_TOLERANCE`), printing a
//! one-table summary per section either way. The memory section is
//! skipped (with a note) when `BENCH_memory.json` is absent.
//!
//! A third section gates serving: `cargo run --release --bin load_gen`
//! writes `BENCH_serve.json` (closed/open-loop p50/p95/p99 latency ms
//! and requests/s per bit width), diffed against
//! `BENCH_serve_baseline.json` the same way. Throughput rows carry
//! `"higher_is_better": true`, flipping the regression direction: a
//! >tolerance *drop* in requests/s fails. Skipped (with a note) when
//! `BENCH_serve.json` is absent.
//!
//! Baseline rows with a value `<= 0` are *uncalibrated* placeholders:
//! they pin the expected row set without enforcing a number (CI hardware
//! differs from dev machines, so a baseline must be recorded on the
//! machine that checks it). The run prints the total uncalibrated count;
//! `--require-calibrated` turns any uncalibrated row into a failure. To
//! (re)calibrate on the reference machine:
//!
//! ```bash
//! cargo bench --bench quant_kernels
//! cargo run --bin perf_gate -- --write-baseline
//! ```
//!
//! The gate also pins the *grid*: a current row absent from the baseline
//! (`new`) or a baseline row absent from the current record (`missing`)
//! fails the gate — silent grid drift would otherwise let rows drop out
//! of enforcement unnoticed. One carve-out: a current row whose *method*
//! name appears nowhere in the baseline is a freshly landed benchmark
//! (`new method`) and is treated as an uncalibrated pin instead of a
//! failure — a PR that adds a kernel should not have to fabricate its
//! own numbers to keep CI green. New `(bits, threads)` combinations of a
//! method the baseline already knows still fail. When a bench grid
//! legitimately changes, rebaseline in the same PR (`--write-baseline`
//! refreshes both baselines and stamps `host_threads` with the
//! recording machine's core count).

use std::process::ExitCode;

use anyhow::{anyhow, Result};

use beacon_ptq::coordinator::report::Table;
use beacon_ptq::util::cli::Args;
use beacon_ptq::util::json::Value;

#[derive(Debug, Clone, PartialEq)]
struct PerfRow {
    method: String,
    bits: String,
    threads: usize,
    /// the gated measurement: ns/channel, peak bytes, latency ms or
    /// requests/s, per section
    value: f64,
    /// throughput-style row (requests/s): a *drop* is the regression.
    /// Read from the optional `higher_is_better` record field.
    higher_is_better: bool,
}

impl PerfRow {
    fn key(&self) -> (&str, &str, usize) {
        (&self.method, &self.bits, self.threads)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Verdict {
    Ok,
    Faster,
    Regression,
    New,
    /// The whole *method* is absent from the baseline: a benchmark that
    /// landed in this PR. Passes the gate as an uncalibrated pin.
    NewMethod,
    Uncalibrated,
}

impl Verdict {
    fn label(&self) -> &'static str {
        match self {
            Verdict::Ok => "ok",
            Verdict::Faster => "faster",
            Verdict::Regression => "REGRESSION",
            Verdict::New => "new",
            Verdict::NewMethod => "new method",
            Verdict::Uncalibrated => "uncalibrated",
        }
    }
}

/// One compared row: the current measurement, the baseline it was held
/// against (if any), and the relative change in percent.
#[derive(Debug)]
struct Comparison {
    current: PerfRow,
    baseline: Option<f64>,
    delta_pct: Option<f64>,
    verdict: Verdict,
}

/// Diff `current` against `baseline` row-by-row (keyed by
/// `(method, bits, threads)`). Returns the comparisons in current-record
/// order plus the baseline rows the current record no longer carries.
fn compare(
    baseline: &[PerfRow],
    current: &[PerfRow],
    tolerance_pct: f64,
) -> (Vec<Comparison>, Vec<PerfRow>) {
    let mut out = Vec::with_capacity(current.len());
    for cur in current {
        let base = baseline.iter().find(|b| b.key() == cur.key());
        let cmp = match base {
            None => {
                let method_known =
                    baseline.iter().any(|b| b.method == cur.method);
                Comparison {
                    current: cur.clone(),
                    baseline: None,
                    delta_pct: None,
                    verdict: if method_known {
                        Verdict::New
                    } else {
                        Verdict::NewMethod
                    },
                }
            }
            Some(b) if b.value <= 0.0 => Comparison {
                current: cur.clone(),
                baseline: Some(b.value),
                delta_pct: None,
                verdict: Verdict::Uncalibrated,
            },
            Some(b) => {
                let delta = 100.0 * (cur.value - b.value) / b.value;
                // for higher-is-better rows (throughput) a drop is the
                // regression: flip the sign before judging, display raw
                let judged = if cur.higher_is_better { -delta } else { delta };
                let verdict = if judged > tolerance_pct {
                    Verdict::Regression
                } else if judged < -tolerance_pct {
                    Verdict::Faster
                } else {
                    Verdict::Ok
                };
                Comparison {
                    current: cur.clone(),
                    baseline: Some(b.value),
                    delta_pct: Some(delta),
                    verdict,
                }
            }
        };
        out.push(cmp);
    }
    let missing: Vec<PerfRow> = baseline
        .iter()
        .filter(|b| !current.iter().any(|c| c.key() == b.key()))
        .cloned()
        .collect();
    (out, missing)
}

fn load_rows(path: &str, value_key: &str) -> Result<Vec<PerfRow>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("read {path}: {e}"))?;
    parse_rows(&text, value_key).map_err(|e| anyhow!("{path}: {e:#}"))
}

fn parse_rows(text: &str, value_key: &str) -> Result<Vec<PerfRow>> {
    let v = Value::parse(text).map_err(|e| anyhow!("{e}"))?;
    let results = v
        .get("results")
        .and_then(Value::as_arr)
        .ok_or_else(|| anyhow!("missing results[] array"))?;
    let mut rows = Vec::with_capacity(results.len());
    for (i, r) in results.iter().enumerate() {
        let field = |k: &str| {
            r.get(k).ok_or_else(|| anyhow!("results[{i}] missing '{k}'"))
        };
        rows.push(PerfRow {
            method: field("method")?
                .as_str()
                .ok_or_else(|| anyhow!("results[{i}].method not a string"))?
                .to_string(),
            bits: field("bits")?
                .as_str()
                .ok_or_else(|| anyhow!("results[{i}].bits not a string"))?
                .to_string(),
            threads: field("threads")?
                .as_usize()
                .ok_or_else(|| anyhow!("results[{i}].threads not a number"))?,
            value: field(value_key)?
                .as_f64()
                .ok_or_else(|| anyhow!("results[{i}].{value_key} not a number"))?,
            higher_is_better: r
                .get("higher_is_better")
                .and_then(Value::as_bool)
                .unwrap_or(false),
        });
    }
    Ok(rows)
}

fn fmt_value(v: Option<f64>, decimals: usize) -> String {
    match v {
        Some(x) if x > 0.0 => format!("{x:.decimals$}"),
        _ => "—".to_string(),
    }
}

/// What one gated section concluded: whether it passed and how many of
/// its baseline rows are uncalibrated placeholders.
#[derive(Debug, Clone, Copy)]
struct SectionOutcome {
    pass: bool,
    uncalibrated: usize,
}

/// Run one gate section (latency or memory): load both records, diff,
/// print the table and any FAIL lines, and return the outcome.
fn gate_section(
    label: &str,
    value_key: &str,
    baseline_path: &str,
    current_path: &str,
    tolerance: f64,
    unit: &str,
    decimals: usize,
) -> Result<SectionOutcome> {
    let baseline = load_rows(baseline_path, value_key)?;
    let current = load_rows(current_path, value_key)?;
    let (cmps, missing) = compare(&baseline, &current, tolerance);

    let bh = format!("baseline {unit}");
    let ch = format!("current {unit}");
    let mut t = Table::new(
        &format!(
            "{label} gate — {current_path} vs {baseline_path} (tolerance {tolerance}%)"
        ),
        &["method", "bits", "threads", bh.as_str(), ch.as_str(), "Δ%", "verdict"],
    );
    for c in &cmps {
        t.row(vec![
            c.current.method.clone(),
            c.current.bits.clone(),
            c.current.threads.to_string(),
            fmt_value(c.baseline, decimals),
            fmt_value(Some(c.current.value), decimals),
            c.delta_pct.map(|d| format!("{d:+.1}")).unwrap_or_else(|| "—".to_string()),
            c.verdict.label().to_string(),
        ]);
    }
    println!("{}", t.render());
    for m in &missing {
        println!(
            "warning: baseline row {}/{}/t{} missing from {current_path}",
            m.method, m.bits, m.threads
        );
    }

    let count = |v: Verdict| cmps.iter().filter(|c| c.verdict == v).count();
    let regressions = count(Verdict::Regression);
    let new_rows = count(Verdict::New);
    let new_methods = count(Verdict::NewMethod);
    let uncalibrated = count(Verdict::Uncalibrated) + new_methods;
    let enforced = cmps.len() - new_rows - uncalibrated;
    println!(
        "{label} calibration: {enforced} enforced row(s), {uncalibrated} \
         uncalibrated placeholder(s) ({value_key} <= 0)"
    );
    if regressions > 0 {
        println!("FAIL: {regressions} {label} row(s) regressed more than {tolerance}%");
    }
    if new_rows > 0 {
        println!(
            "FAIL: {new_rows} {label} bench row(s) missing from the baseline grid — \
             rebaseline with: cargo run --bin perf_gate -- --write-baseline"
        );
    }
    if new_methods > 0 {
        println!(
            "note: {new_methods} {label} row(s) from method(s) the baseline has \
             never seen — passing as uncalibrated; add placeholder rows or \
             rebaseline to pin them"
        );
    }
    if !missing.is_empty() {
        println!(
            "FAIL: {} {label} baseline row(s) missing from {current_path} — the \
             bench grid drifted; rebaseline if intentional",
            missing.len()
        );
    }
    let pass = gate_passes(&cmps, &missing);
    if pass {
        println!("{label} gate passed ({} rows compared)", cmps.len());
    }
    Ok(SectionOutcome { pass, uncalibrated })
}

/// Copy `current_path` over `baseline_path`, stamping `host_threads`
/// with the recording machine's core count so the baseline says where
/// its numbers came from.
fn write_baseline(current_path: &str, baseline_path: &str) -> Result<()> {
    let text = std::fs::read_to_string(current_path)
        .map_err(|e| anyhow!("read {current_path}: {e}"))?;
    let mut v = Value::parse(&text).map_err(|e| anyhow!("{current_path}: {e}"))?;
    if let Value::Obj(m) = &mut v {
        let host = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        m.insert("host_threads".to_string(), Value::Num(host as f64));
    }
    std::fs::write(baseline_path, v.to_json())
        .map_err(|e| anyhow!("write {baseline_path}: {e}"))?;
    println!("rebaselined {baseline_path} from {current_path} (host_threads stamped)");
    Ok(())
}

fn run() -> Result<bool> {
    let args = Args::from_env();
    let baseline_path = args.str("baseline", "BENCH_baseline.json");
    let current_path = args.str("current", "BENCH_quant.json");
    let mem_baseline_path =
        args.str("memory-baseline", "BENCH_memory_baseline.json");
    let mem_current_path = args.str("memory-current", "BENCH_memory.json");
    let serve_baseline_path =
        args.str("serve-baseline", "BENCH_serve_baseline.json");
    let serve_current_path = args.str("serve-current", "BENCH_serve.json");
    if args.switch("write-baseline") {
        write_baseline(&current_path, &baseline_path)?;
        if std::path::Path::new(&mem_current_path).exists() {
            write_baseline(&mem_current_path, &mem_baseline_path)?;
        } else {
            println!(
                "memory baseline not written: {mem_current_path} not found \
                 (run cargo bench --bench quant_kernels first)"
            );
        }
        if std::path::Path::new(&serve_current_path).exists() {
            write_baseline(&serve_current_path, &serve_baseline_path)?;
        } else {
            println!(
                "serve baseline not written: {serve_current_path} not found \
                 (run cargo run --release --bin load_gen first)"
            );
        }
        return Ok(true);
    }
    let env_tol = std::env::var("PERF_GATE_TOLERANCE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(25.0);
    let tolerance = args.f64("tolerance-pct", env_tol);

    let latency = gate_section(
        "perf",
        "ns_per_channel",
        &baseline_path,
        &current_path,
        tolerance,
        "ns/ch",
        1,
    )?;
    let memory = if std::path::Path::new(&mem_current_path).exists() {
        Some(gate_section(
            "memory",
            "peak_bytes",
            &mem_baseline_path,
            &mem_current_path,
            tolerance,
            "bytes",
            0,
        )?)
    } else {
        println!(
            "memory gate skipped: {mem_current_path} not found \
             (cargo bench --bench quant_kernels writes it)"
        );
        None
    };
    let serve = if std::path::Path::new(&serve_current_path).exists() {
        Some(gate_section(
            "serve",
            "value",
            &serve_baseline_path,
            &serve_current_path,
            tolerance,
            "value",
            3,
        )?)
    } else {
        println!(
            "serve gate skipped: {serve_current_path} not found \
             (cargo run --release --bin load_gen writes it)"
        );
        None
    };

    let mem_uncal = match &memory {
        Some(m) => m.uncalibrated,
        None => 0,
    };
    let serve_uncal = match &serve {
        Some(s) => s.uncalibrated,
        None => 0,
    };
    let uncalibrated = latency.uncalibrated + mem_uncal + serve_uncal;
    println!("total uncalibrated placeholder row(s): {uncalibrated}");
    if uncalibrated > 0 {
        println!(
            "record baselines on the CI class of machine with: \
             cargo run --bin perf_gate -- --write-baseline"
        );
    }
    if args.switch("require-calibrated") && uncalibrated > 0 {
        println!(
            "FAIL: --require-calibrated set but {uncalibrated} baseline row(s) \
             are uncalibrated placeholders"
        );
        return Ok(false);
    }
    let mem_pass = match &memory {
        Some(m) => m.pass,
        None => true,
    };
    let serve_pass = match &serve {
        Some(s) => s.pass,
        None => true,
    };
    Ok(latency.pass && mem_pass && serve_pass)
}

/// The gate decision: no regressions and no grid drift in either
/// direction (every current row is pinned by the baseline, every
/// baseline row is still measured). Rows from methods the baseline has
/// never seen (`NewMethod`) are uncalibrated pins, not drift.
fn gate_passes(cmps: &[Comparison], missing: &[PerfRow]) -> bool {
    missing.is_empty()
        && !cmps
            .iter()
            .any(|c| matches!(c.verdict, Verdict::Regression | Verdict::New))
}

fn main() -> ExitCode {
    match run() {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::from(1),
        Err(e) => {
            eprintln!("perf_gate error: {e:#}");
            ExitCode::from(2)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(method: &str, bits: &str, threads: usize, value: f64) -> PerfRow {
        PerfRow {
            method: method.to_string(),
            bits: bits.to_string(),
            threads,
            value,
            higher_is_better: false,
        }
    }

    fn rps_row(method: &str, bits: &str, threads: usize, value: f64) -> PerfRow {
        PerfRow { higher_is_better: true, ..row(method, bits, threads, value) }
    }

    #[test]
    fn regression_detected_beyond_tolerance() {
        let base = vec![row("beacon", "2-bit", 1, 100.0)];
        let cur = vec![row("beacon", "2-bit", 1, 126.0)];
        let (cmps, missing) = compare(&base, &cur, 25.0);
        assert!(missing.is_empty());
        assert_eq!(cmps[0].verdict, Verdict::Regression);
        // 25% exactly is within tolerance
        let cur = vec![row("beacon", "2-bit", 1, 125.0)];
        let (cmps, _) = compare(&base, &cur, 25.0);
        assert_eq!(cmps[0].verdict, Verdict::Ok);
    }

    #[test]
    fn faster_new_uncalibrated_and_missing() {
        let base = vec![
            row("beacon", "2-bit", 1, 100.0),
            row("rtn", "2-bit", 1, 0.0),
            row("gptq", "2-bit", 1, 50.0),
        ];
        let cur = vec![
            row("beacon", "2-bit", 1, 60.0),
            row("rtn", "2-bit", 1, 40.0),
            // known method, unseen (bits, threads) combo: hard failure
            row("beacon", "2+4", 2, 9.0),
        ];
        let (cmps, missing) = compare(&base, &cur, 25.0);
        assert_eq!(cmps[0].verdict, Verdict::Faster);
        assert_eq!(cmps[1].verdict, Verdict::Uncalibrated);
        assert_eq!(cmps[2].verdict, Verdict::New);
        assert_eq!(missing, vec![row("gptq", "2-bit", 1, 50.0)]);
    }

    #[test]
    fn unseen_method_is_uncalibrated_pin_not_drift() {
        let base = vec![row("beacon", "2-bit", 1, 100.0)];
        let cur = vec![
            row("beacon", "2-bit", 1, 101.0),
            // a benchmark that landed in this PR: no baseline row carries
            // its method name anywhere, so it passes as an uncalibrated pin
            row("packed-gemm", "4-bit", 1, 12.0),
            row("packed-gemm", "4-bit", 4, 4.0),
        ];
        let (cmps, missing) = compare(&base, &cur, 25.0);
        assert_eq!(cmps[1].verdict, Verdict::NewMethod);
        assert_eq!(cmps[2].verdict, Verdict::NewMethod);
        assert!(missing.is_empty());
        assert!(gate_passes(&cmps, &missing));
        // but once the baseline knows the method, any unseen combo of it
        // is grid drift again
        let base = vec![row("packed-gemm", "4-bit", 1, 0.0)];
        let cur = vec![
            row("packed-gemm", "4-bit", 1, 12.0),
            row("packed-gemm", "2-bit", 1, 8.0),
        ];
        let (cmps, missing) = compare(&base, &cur, 25.0);
        assert_eq!(cmps[0].verdict, Verdict::Uncalibrated);
        assert_eq!(cmps[1].verdict, Verdict::New);
        assert!(!gate_passes(&cmps, &missing));
    }

    #[test]
    fn higher_is_better_flips_regression_direction() {
        let base = vec![rps_row("closed.rps", "4-bit", 2, 1000.0)];
        // throughput dropped 40% -> regression
        let cur = vec![rps_row("closed.rps", "4-bit", 2, 600.0)];
        let (cmps, _) = compare(&base, &cur, 25.0);
        assert_eq!(cmps[0].verdict, Verdict::Regression);
        // raw delta is still reported as the signed change
        assert!((cmps[0].delta_pct.unwrap() + 40.0).abs() < 1e-9);
        // throughput up 40% -> faster, not a failure
        let cur = vec![rps_row("closed.rps", "4-bit", 2, 1400.0)];
        let (cmps, missing) = compare(&base, &cur, 25.0);
        assert_eq!(cmps[0].verdict, Verdict::Faster);
        assert!(gate_passes(&cmps, &missing));
        // within tolerance either way -> ok
        let cur = vec![rps_row("closed.rps", "4-bit", 2, 900.0)];
        let (cmps, _) = compare(&base, &cur, 25.0);
        assert_eq!(cmps[0].verdict, Verdict::Ok);
        // a latency-style row with the same numbers regresses on the
        // *increase* instead
        let base = vec![row("closed.p99_ms", "4-bit", 2, 1000.0)];
        let cur = vec![row("closed.p99_ms", "4-bit", 2, 1400.0)];
        let (cmps, _) = compare(&base, &cur, 25.0);
        assert_eq!(cmps[0].verdict, Verdict::Regression);
    }

    #[test]
    fn uncalibrated_placeholder_pins_throughput_rows_too() {
        let base = vec![rps_row("open.rps", "2-bit", 2, 0.0)];
        let cur = vec![rps_row("open.rps", "2-bit", 2, 12345.6)];
        let (cmps, missing) = compare(&base, &cur, 25.0);
        assert_eq!(cmps[0].verdict, Verdict::Uncalibrated);
        assert!(gate_passes(&cmps, &missing));
    }

    #[test]
    fn rows_match_on_full_key() {
        // same method+bits at another thread count is a different row
        let base = vec![row("beacon", "2-bit", 1, 100.0)];
        let cur = vec![row("beacon", "2-bit", 4, 100.0)];
        let (cmps, missing) = compare(&base, &cur, 25.0);
        assert_eq!(cmps[0].verdict, Verdict::New);
        assert_eq!(missing.len(), 1);
    }

    #[test]
    fn gate_fails_on_grid_drift_both_directions() {
        let base = vec![row("beacon", "2-bit", 1, 100.0), row("rtn", "2-bit", 1, 0.0)];
        // healthy: same grid, within tolerance (uncalibrated row allowed)
        let cur = vec![row("beacon", "2-bit", 1, 101.0), row("rtn", "2-bit", 1, 55.0)];
        let (cmps, missing) = compare(&base, &cur, 25.0);
        assert!(gate_passes(&cmps, &missing));
        // current grew a combo of a known method the baseline does not
        // pin -> fail (an entirely unknown method would pass; see
        // unseen_method_is_uncalibrated_pin_not_drift)
        let mut grown = cur.clone();
        grown.push(row("beacon", "4-bit", 1, 70.0));
        let (cmps, missing) = compare(&base, &grown, 25.0);
        assert!(!gate_passes(&cmps, &missing));
        // current dropped a baseline row -> fail
        let shrunk = vec![row("beacon", "2-bit", 1, 101.0)];
        let (cmps, missing) = compare(&base, &shrunk, 25.0);
        assert!(!gate_passes(&cmps, &missing));
        // and a plain regression still fails
        let slow = vec![row("beacon", "2-bit", 1, 200.0), row("rtn", "2-bit", 1, 55.0)];
        let (cmps, missing) = compare(&base, &slow, 25.0);
        assert!(!gate_passes(&cmps, &missing));
    }

    #[test]
    fn parses_bench_record_shape() {
        let text = r#"{
  "bench": "quant_kernels",
  "layer": {"rows": 512, "n": 64, "channels": 128},
  "host_threads": 8,
  "results": [
    {"method": "beacon", "bits": "2-bit", "threads": 1, "median_ns": 123456, "ns_per_channel": 964.5},
    {"method": "mixed-plan", "bits": "2+4", "threads": 4, "median_ns": 9999, "ns_per_channel": 20.8}
  ]
}"#;
        let rows = parse_rows(text, "ns_per_channel").unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].method, "beacon");
        assert_eq!(rows[1].threads, 4);
        assert!((rows[1].value - 20.8).abs() < 1e-9);
        assert!(parse_rows("{}", "ns_per_channel").is_err());
        assert!(parse_rows("{\"results\": [{\"method\": \"x\"}]}", "ns_per_channel")
            .is_err());
    }

    #[test]
    fn parses_memory_record_shape() {
        let text = r#"{
  "bench": "quant_memory",
  "layer": {"rows": 512, "n": 64, "channels": 128},
  "host_threads": 8,
  "results": [
    {"method": "beacon", "bits": "2-bit", "threads": 1, "peak_bytes": 1048576.0},
    {"method": "rtn", "bits": "2-bit", "threads": 1, "peak_bytes": 262144.0}
  ]
}"#;
        let rows = parse_rows(text, "peak_bytes").unwrap();
        assert_eq!(rows.len(), 2);
        assert!((rows[0].value - 1_048_576.0).abs() < 1e-9);
        // the latency key is absent from memory records
        assert!(parse_rows(text, "ns_per_channel").is_err());
    }

    #[test]
    fn value_formatting_per_section() {
        assert_eq!(fmt_value(Some(964.53), 1), "964.5");
        assert_eq!(fmt_value(Some(1048576.0), 0), "1048576");
        assert_eq!(fmt_value(Some(0.4321), 3), "0.432");
        // placeholders and absent baselines render as em dash
        assert_eq!(fmt_value(Some(0.0), 0), "—");
        assert_eq!(fmt_value(None, 1), "—");
    }

    #[test]
    fn parses_serve_record_shape() {
        let text = r#"{
  "bench": "load_gen",
  "host_threads": 8,
  "results": [
    {"method": "closed.p50_ms", "bits": "4-bit", "threads": 2, "value": 0.42},
    {"method": "closed.rps", "bits": "4-bit", "threads": 2, "value": 9800.5, "higher_is_better": true}
  ]
}"#;
        let rows = parse_rows(text, "value").unwrap();
        assert_eq!(rows.len(), 2);
        assert!(!rows[0].higher_is_better);
        assert!(rows[1].higher_is_better);
        assert!((rows[1].value - 9800.5).abs() < 1e-9);
    }
}
