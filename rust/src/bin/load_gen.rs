//! `load_gen` — deterministic load generator for the serve subsystem,
//! and the producer of the machine-readable serving perf record.
//!
//! Drives the batching server over a synthetic packed checkpoint (no
//! artifacts needed) in two arrival patterns:
//!
//! * **closed loop** — `--clients N` threads, each submitting its next
//!   request only after the previous response arrives. Measures the
//!   server's throughput ceiling under self-throttling clients.
//! * **open loop** — one dispatcher submitting on a seeded-exponential
//!   arrival clock (`--rate` req/s, `SplitMix64` inter-arrival gaps),
//!   the pattern real traffic follows. Submission blocks when the
//!   bounded queue is full (backpressure), so a saturated server shows
//!   up as queue-wait latency rather than unbounded memory.
//!
//! Every response is verified **bit-identical** to the sequential
//! single-request packed path (`PackedModel::forward_one`) — the run
//! aborts on the first mismatch, making this binary double as the
//! end-to-end determinism check for batched serving.
//!
//! Writes `BENCH_serve.json` (`--out`): one row per
//! `mode.metric × bits × workers` with p50/p95/p99 latency (ms) and
//! requests/s; throughput rows carry `"higher_is_better": true` so the
//! perf gate flips their regression direction. Diffed against
//! `BENCH_serve_baseline.json` by `perf_gate`'s serve section.
//!
//! The defaults (2 workers, 4-bit + 2-bit, both modes) produce exactly
//! the committed baseline grid; CI runs them as a release smoke.

use std::sync::Arc;

use anyhow::{bail, Result};

use beacon_ptq::coordinator::report::serve_table;
use beacon_ptq::data::rng::SplitMix64;
use beacon_ptq::obs::{self, TrackingAlloc};
use beacon_ptq::quant::alphabet::BitWidth;
use beacon_ptq::serve::{
    synthetic_store, PackedModel, Response, ServeConfig, ServeReport, Server,
};
use beacon_ptq::util::cli::Args;
use beacon_ptq::util::prop::Gen;

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

struct RunCfg {
    requests: usize,
    clients: usize,
    rate: f64,
    serve: ServeConfig,
}

/// One bench row: `method` folds mode and metric (`closed.p50_ms`,
/// `open.rps`, ...) so the perf gate's `(method, bits, threads)` key
/// works unchanged.
struct Row {
    method: String,
    bits: String,
    threads: usize,
    value: f64,
    higher_is_better: bool,
}

fn verify(model: &PackedModel, input: &[f64], resp: &Response) -> Result<()> {
    let want = model.forward_one(input, 1);
    if resp.output.len() != want.len() {
        bail!("request {}: output length {} != {}", resp.id, resp.output.len(), want.len());
    }
    for (j, (a, b)) in resp.output.iter().zip(&want).enumerate() {
        if a.to_bits() != b.to_bits() {
            bail!(
                "request {}: output[{j}] = {a:e} differs from sequential \
                 packed path {b:e} — batched serving broke determinism",
                resp.id
            );
        }
    }
    Ok(())
}

/// Pre-generate the workload: deterministic request vectors, one per
/// request, seeded per width so closed and open loops replay the same
/// traffic.
fn inputs(n: usize, dim: usize, width: BitWidth, seed: u64) -> Vec<Vec<f64>> {
    let mut g = Gen {
        rng: SplitMix64::new(seed ^ (u64::from(width.storage_bits()) << 32)),
    };
    (0..n).map(|_| g.vec_normal(dim, 1.0)).collect()
}

fn run_closed(
    model: &Arc<PackedModel>,
    cfg: &RunCfg,
    width: BitWidth,
) -> Result<ServeReport> {
    let xs = Arc::new(inputs(
        cfg.requests,
        model.input_dim(),
        width,
        0x10AD_C105,
    ));
    obs::memory::reset_peak();
    let mut sc = cfg.serve.clone();
    sc.label = format!("closed {}", width.label());
    let (server, client) = Server::start(Arc::clone(model), sc);
    let clients = cfg.clients.max(1);
    let joins: Vec<_> = (0..clients)
        .map(|c| {
            let client = client.clone();
            let xs = Arc::clone(&xs);
            std::thread::spawn(move || {
                let mut got = Vec::new();
                // client c owns requests c, c+clients, c+2·clients, ...
                let mut r = c;
                while r < xs.len() {
                    let sp = obs::span_args("serve", || {
                        (format!("request[{r}]"), Vec::new())
                    });
                    let resp = client.submit(xs[r].clone()).wait();
                    sp.finish();
                    got.push((r, resp));
                    r += clients;
                }
                got
            })
        })
        .collect();
    drop(client);
    let mut responses = Vec::with_capacity(cfg.requests);
    for j in joins {
        responses.extend(j.join().expect("load_gen: client thread panicked"));
    }
    let report = server.shutdown();
    for (r, resp) in &responses {
        verify(model, &xs[*r], resp)?;
    }
    println!(
        "closed {}: verified {} responses bit-identical to the \
         sequential packed path",
        width.label(),
        responses.len()
    );
    Ok(report)
}

fn run_open(
    model: &Arc<PackedModel>,
    cfg: &RunCfg,
    width: BitWidth,
) -> Result<ServeReport> {
    let xs = inputs(cfg.requests, model.input_dim(), width, 0x10AD_0BE4);
    obs::memory::reset_peak();
    let mut sc = cfg.serve.clone();
    sc.label = format!("open {}", width.label());
    let (server, client) = Server::start(Arc::clone(model), sc);
    // seeded exponential inter-arrival gaps: a Poisson arrival process
    // replayed identically on every run
    let mut arrivals = SplitMix64::new(0xA441_7A1 ^ u64::from(width.storage_bits()));
    let mut handles = Vec::with_capacity(cfg.requests);
    for x in &xs {
        let u = arrivals.next_f64().max(1e-12);
        let gap_secs = -u.ln() / cfg.rate.max(1.0);
        std::thread::sleep(std::time::Duration::from_secs_f64(gap_secs));
        // blocking submit: when the queue is full the arrival clock
        // stalls (backpressure) — see docs/SERVE.md on reading open-loop
        // latency under saturation
        handles.push(client.submit(x.clone()));
    }
    drop(client);
    let responses: Vec<Response> =
        handles.into_iter().map(|h| h.wait()).collect();
    let report = server.shutdown();
    for (x, resp) in xs.iter().zip(&responses) {
        verify(model, x, resp)?;
    }
    println!(
        "open {}: verified {} responses bit-identical to the \
         sequential packed path",
        width.label(),
        responses.len()
    );
    Ok(report)
}

fn rows_from(report: &ServeReport, mode: &str, bits: &str, out: &mut Vec<Row>) {
    let ms = |ns: u64| ns as f64 / 1e6;
    let mut push = |metric: &str, value: f64, hib: bool| {
        out.push(Row {
            method: format!("{mode}.{metric}"),
            bits: bits.to_string(),
            threads: report.workers,
            value,
            higher_is_better: hib,
        });
    };
    push("p50_ms", ms(report.latency_ns.p50), false);
    push("p95_ms", ms(report.latency_ns.p95), false);
    push("p99_ms", ms(report.latency_ns.p99), false);
    push("rps", report.requests_per_sec(), true);
}

fn write_record(path: &str, rows: &[Row], cfg: &RunCfg) -> Result<()> {
    let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut s = String::new();
    s.push_str("{\n  \"bench\": \"load_gen\",\n");
    s.push_str(&format!(
        "  \"workload\": {{\"requests\": {}, \"clients\": {}, \"rate\": {}, \
         \"max_batch\": {}, \"deadline_ms\": {}, \"queue_capacity\": {}}},\n",
        cfg.requests,
        cfg.clients,
        cfg.rate,
        cfg.serve.max_batch,
        cfg.serve.deadline.as_secs_f64() * 1e3,
        cfg.serve.queue_capacity,
    ));
    s.push_str(&format!("  \"host_threads\": {host},\n"));
    s.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"method\": \"{}\", \"bits\": \"{}\", \"threads\": {}, \
             \"value\": {:.4}",
            r.method, r.bits, r.threads, r.value
        ));
        if r.higher_is_better {
            s.push_str(", \"higher_is_better\": true");
        }
        s.push_str(if i + 1 == rows.len() { "}\n" } else { "},\n" });
    }
    s.push_str("  ]\n}\n");
    std::fs::write(path, &s)?;
    println!("wrote {path} ({} rows, host_threads={host})", rows.len());
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let trace_to = args
        .get("trace")
        .map(String::from)
        .or_else(|| args.switch("trace").then(|| "load_gen_trace.json".to_string()))
        .or_else(obs::trace_env);
    if trace_to.is_some() {
        obs::enable();
    }

    let layers = args.usize("layers", 4);
    let dim = args.usize("dim", 192);
    let cfg = RunCfg {
        requests: args.usize("requests", 256),
        clients: args.usize("clients", 4),
        rate: args.f64("rate", 2000.0),
        serve: ServeConfig {
            label: String::new(),
            max_batch: args.usize("batch", 8),
            deadline: std::time::Duration::from_secs_f64(
                args.f64("deadline-ms", 2.0) / 1e3,
            ),
            workers: args.usize("workers", 2),
            threads: args.usize("threads", 0),
            queue_capacity: args.usize("queue-cap", 64),
        },
    };
    let mode = args.str("mode", "both");
    if !matches!(mode.as_str(), "both" | "closed" | "open") {
        bail!("--mode must be closed, open, or both (got '{mode}')");
    }
    let widths: Vec<BitWidth> = {
        let csv = args.csv("bits");
        let specs = if csv.is_empty() {
            vec!["4".to_string(), "2".to_string()]
        } else {
            csv
        };
        specs
            .iter()
            .map(|s| {
                BitWidth::parse(s)
                    .ok_or_else(|| anyhow::anyhow!("bad bit width '{s}'"))
            })
            .collect::<Result<_>>()?
    };
    let out = args.str("out", "BENCH_serve.json");

    let mut rows = Vec::new();
    for width in widths {
        let store = synthetic_store(layers, dim, width, 0x5EED_BEAC);
        let model = Arc::new(PackedModel::from_store(store)?);
        println!(
            "model: {} layers × {dim}×{dim} at {} ({} packed resident bytes)",
            model.layer_count(),
            width.label(),
            model.resident_bytes()
        );
        if mode == "both" || mode == "closed" {
            let report = run_closed(&model, &cfg, width)?;
            print!("{}", serve_table(&report).render());
            rows_from(&report, "closed", &width.label(), &mut rows);
        }
        if mode == "both" || mode == "open" {
            let report = run_open(&model, &cfg, width)?;
            print!("{}", serve_table(&report).render());
            rows_from(&report, "open", &width.label(), &mut rows);
        }
    }
    write_record(&out, &rows, &cfg)?;

    if let Some(path) = trace_to {
        obs::write_chrome_trace(std::path::Path::new(&path))?;
        println!("trace written to {path}");
    }
    Ok(())
}
