//! # beacon-ptq
//!
//! A production-grade reproduction of **"Beacon: Post-Training Quantization
//! with Integrated Grid Selection"** (Zhang & Saab, 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the quantization *coordinator*: a layer-
//!   sequential, channel-parallel PTQ pipeline with error-correction
//!   recapture, centering, LayerNorm tuning, evaluation, baselines
//!   (GPTQ / RTN / COMQ) and a native linear-algebra substrate.
//! * **Layer 2 (python/compile, build time only)** — JAX ViT graphs lowered
//!   AOT to HLO text artifacts executed here through PJRT.
//! * **Layer 1 (python/compile/kernels, build time only)** — the Beacon
//!   inner sweep as a Pallas kernel embedded in those artifacts.
//!
//! Python never runs at quantization/serving time: `artifacts/` is built
//! once by `make artifacts` and the `beacon` binary is self-contained.
//!
//! ## Quick start
//!
//! The pipeline consumes a [`config::QuantPlan`] — one resolved
//! `(method, bits, opts)` assignment per quantizable layer, compiled by
//! [`config::PlanBuilder`] from defaults plus glob overrides (last match
//! wins). A flat [`config::QuantConfig`] still works through the
//! `quantize_cfg` shim, which compiles it into a uniform plan.
//!
//! ```no_run
//! use beacon_ptq::config::{PlanBuilder, QuantConfig};
//! use beacon_ptq::coordinator::Pipeline;
//!
//! let mut pipe = Pipeline::from_artifacts("artifacts", "tiny-sim").unwrap();
//! // attention at 2 bits, MLP at 4 — methods and widths mix per layer
//! let plan = PlanBuilder::uniform(&QuantConfig { bits: 2.0, ..QuantConfig::default() })
//!     .override_layers("blocks.*.fc?.w", "comq:4").unwrap()
//!     .build(pipe.quantizable()).unwrap();
//! let report = pipe.quantize(&plan).unwrap();
//! println!("top-1 {:.2}% at {:.2} bits/weight",
//!     100.0 * report.top1, report.effective_bits);
//! // reproducible: the resolved plan round-trips through one manifest
//! std::fs::write("plan.cfg", plan.to_manifest()).unwrap();
//! ```

pub mod config;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod model;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod util;

pub use config::{LayerAssignment, Method, PlanBuilder, QuantConfig, QuantPlan, SearchSpace};
pub use coordinator::Pipeline;
pub use obs::MetricsReport;
pub use quant::{LayerCtx, LayerQuant, Quantizer};
