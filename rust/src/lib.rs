//! # beacon-ptq
//!
//! A production-grade reproduction of **"Beacon: Post-Training Quantization
//! with Integrated Grid Selection"** (Zhang & Saab, 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the quantization *coordinator*: a layer-
//!   sequential, channel-parallel PTQ pipeline with error-correction
//!   recapture, centering, LayerNorm tuning, evaluation, baselines
//!   (GPTQ / RTN / COMQ) and a native linear-algebra substrate.
//! * **Layer 2 (python/compile, build time only)** — JAX ViT graphs lowered
//!   AOT to HLO text artifacts executed here through PJRT.
//! * **Layer 1 (python/compile/kernels, build time only)** — the Beacon
//!   inner sweep as a Pallas kernel embedded in those artifacts.
//!
//! Python never runs at quantization/serving time: `artifacts/` is built
//! once by `make artifacts` and the `beacon` binary is self-contained.
//!
//! ## Quick start
//!
//! ```no_run
//! use beacon_ptq::config::{QuantConfig, Method};
//! use beacon_ptq::coordinator::Pipeline;
//!
//! let cfg = QuantConfig { bits: 2.0, ..QuantConfig::default() };
//! let mut pipe = Pipeline::from_artifacts("artifacts", "tiny-sim").unwrap();
//! let report = pipe.quantize(&cfg).unwrap();
//! println!("top-1 after 2-bit Beacon: {:.2}%", 100.0 * report.top1);
//! ```

pub mod config;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod util;

pub use config::{Method, QuantConfig};
pub use coordinator::Pipeline;
pub use quant::{LayerCtx, LayerQuant, Quantizer};
