//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them once on the CPU PJRT client, and
//! executes them from the coordinator's hot path. Python is never invoked
//! here — the artifacts directory is the entire L2/L1 interface.

pub mod artifacts;
pub mod client;

pub use artifacts::{Artifacts, Manifest};
pub use client::{literal_f32, literal_f32_1d, literal_i32_1d, Runtime};
