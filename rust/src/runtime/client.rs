//! Thin wrapper over the `xla` crate's PJRT CPU client with a compiled-
//! executable cache.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax ≥ 0.5
//! serialized protos use 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md
//! and DESIGN.md).

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

/// Compiled-executable cache keyed by artifact path. Compilation happens
/// once per (artifact, process); execution is pure Rust → PJRT.
///
/// Interior mutability is `Mutex`-based so `Runtime` (and `Pipeline`) are
/// `Sync`: the engine's layer scheduler may hold `&Pipeline` inside a
/// `Send + Sync` quantizer. Executions still serialize behind the cache
/// lock — the PJRT adapter reports `parallel_safe() == false`, so the
/// lock is uncontended in practice.
pub struct Runtime {
    client: PjRtClient,
    cache: Mutex<HashMap<String, PjRtLoadedExecutable>>,
    /// cumulative (compile_ms, exec_ms, exec_count) for metrics
    stats: Mutex<RuntimeStats>,
}

#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub compile_ms: f64,
    pub exec_ms: f64,
    pub executions: u64,
    pub compilations: u64,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime {
            client,
            cache: Mutex::new(HashMap::new()),
            stats: Mutex::new(RuntimeStats::default()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.lock().unwrap().clone()
    }

    fn compiled(&self, path: &Path) -> Result<()> {
        let key = path.to_string_lossy().to_string();
        // hold the cache lock across the compile: concurrent callers of
        // a not-yet-cached artifact must wait, not compile it twice
        // (check-then-insert across two lock scopes would race now that
        // Runtime is Sync)
        let mut cache = self.cache.lock().unwrap();
        if cache.contains_key(&key) {
            return Ok(());
        }
        let t = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {path:?}"))?;
        let mut stats = self.stats.lock().unwrap();
        stats.compile_ms += t.elapsed().as_secs_f64() * 1e3;
        stats.compilations += 1;
        drop(stats);
        cache.insert(key, exe);
        Ok(())
    }

    /// Execute an artifact. All our graphs are lowered with
    /// `return_tuple=True`, so the single output literal is a tuple which
    /// this unpacks into its elements.
    pub fn exec(&self, path: &Path, inputs: &[Literal]) -> Result<Vec<Literal>> {
        self.compiled(path)?;
        let key = path.to_string_lossy().to_string();
        let cache = self.cache.lock().unwrap();
        let exe = cache.get(&key).expect("just compiled");
        let t = Instant::now();
        let result = exe
            .execute::<Literal>(inputs)
            .with_context(|| format!("execute {path:?}"))?[0][0]
            .to_literal_sync()?;
        drop(cache);
        let mut stats = self.stats.lock().unwrap();
        stats.exec_ms += t.elapsed().as_secs_f64() * 1e3;
        stats.executions += 1;
        drop(stats);
        let parts = result.to_tuple()?;
        Ok(parts)
    }
}

/// Literal from an f32 slice with the given dims.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
    let numel: i64 = dims.iter().product();
    anyhow::ensure!(
        numel as usize == data.len(),
        "literal shape {dims:?} != data len {}",
        data.len()
    );
    Ok(Literal::vec1(data).reshape(dims)?)
}

pub fn literal_f32_1d(data: &[f32]) -> Literal {
    Literal::vec1(data)
}

pub fn literal_i32_1d(data: &[i32]) -> Literal {
    Literal::vec1(data)
}

/// Read an f32 literal back into a Vec (any shape, row-major).
pub fn literal_to_f32(l: &Literal) -> Result<Vec<f32>> {
    Ok(l.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shape_checked() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).is_ok());
    }

    // Runtime execution is covered by rust/tests/runtime_integration.rs,
    // which requires the artifacts bundle (and therefore runs under
    // `make test`, not bare unit tests).
}
