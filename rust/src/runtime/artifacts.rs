//! Artifact manifest: the JSON file `manifest__{cfg}.json` written by the
//! AOT build, describing datasets, weights, HLO graphs and the parameter
//! ordering. Parsed with the in-crate JSON parser and cross-checked
//! against the Rust [`param_spec`] mirror at load time, so an L2/L3 drift
//! fails loudly before any execution.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::model::spec::{param_spec, ViTConfig};
use crate::util::json::Value;

#[derive(Debug, Clone)]
pub struct Manifest {
    pub cfg: ViTConfig,
    pub alph_pad: usize,
    pub eval_batch: usize,
    pub calib_count: usize,
    pub eval_count: usize,
    pub ln_batch: usize,
    pub quantizable: Vec<String>,
    pub weights: PathBuf,
    pub calib: PathBuf,
    pub eval: PathBuf,
    pub vit_logits: PathBuf,
    pub collect_acts: PathBuf,
    pub ln_tune_step: PathBuf,
    /// "NxN'" -> HLO path for the Beacon pallas-kernel artifact
    pub beacon_layer: BTreeMap<String, PathBuf>,
}

#[derive(Debug, Clone)]
pub struct Artifacts {
    pub dir: PathBuf,
    pub manifest: Manifest,
}

impl Artifacts {
    pub fn load(dir: &Path, config_name: &str) -> Result<Artifacts> {
        let mpath = dir.join(format!("manifest__{config_name}.json"));
        let text = std::fs::read_to_string(&mpath).with_context(|| {
            format!(
                "missing {mpath:?} — run `make artifacts` to build the AOT bundle"
            )
        })?;
        let v = Value::parse(&text).context("manifest parse")?;

        let c = v.at(&["config"]);
        let cfg = ViTConfig {
            name: req_str(c, "name")?,
            image: req_usize(c, "image")?,
            channels: req_usize(c, "channels")?,
            patch: req_usize(c, "patch")?,
            d_model: req_usize(c, "d_model")?,
            depth: req_usize(c, "depth")?,
            heads: req_usize(c, "heads")?,
            mlp_ratio: req_usize(c, "mlp_ratio")?,
            num_classes: req_usize(c, "num_classes")?,
        };

        // cross-check the parameter ordering ABI
        let spec = param_spec(&cfg);
        let params = v
            .at(&["params"])
            .as_arr()
            .context("manifest params not an array")?;
        if params.len() != spec.len() {
            bail!(
                "manifest has {} params, Rust spec has {} — L2/L3 drift",
                params.len(),
                spec.len()
            );
        }
        for (p, s) in params.iter().zip(&spec) {
            let arr = p.as_arr().context("param entry")?;
            let name = arr[0].as_str().context("param name")?;
            let shape: Vec<usize> = arr[1]
                .as_arr()
                .context("param shape")?
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect();
            if name != s.name || shape != s.shape {
                bail!(
                    "param ABI mismatch: manifest ({name} {shape:?}) vs rust ({} {:?})",
                    s.name,
                    s.shape
                );
            }
        }

        let quantizable = v
            .at(&["quantizable"])
            .as_arr()
            .context("quantizable")?
            .iter()
            .map(|x| x.as_str().unwrap_or("").to_string())
            .collect();

        let a = v.at(&["artifacts"]);
        let path_of = |key: &str| -> Result<PathBuf> {
            Ok(dir.join(
                a.get(key)
                    .and_then(|x| x.as_str())
                    .with_context(|| format!("artifact '{key}'"))?,
            ))
        };
        let mut beacon_layer = BTreeMap::new();
        if let Some(map) = a.get("beacon_layer").and_then(|x| x.as_obj()) {
            for (k, val) in map {
                beacon_layer.insert(
                    k.clone(),
                    dir.join(val.as_str().context("beacon_layer path")?),
                );
            }
        }

        let manifest = Manifest {
            cfg,
            alph_pad: v.at(&["alph_pad"]).as_usize().context("alph_pad")?,
            eval_batch: v.at(&["eval_batch"]).as_usize().context("eval_batch")?,
            calib_count: v.at(&["calib_count"]).as_usize().context("calib_count")?,
            eval_count: v.at(&["eval_count"]).as_usize().context("eval_count")?,
            ln_batch: v.at(&["ln_batch"]).as_usize().context("ln_batch")?,
            quantizable,
            weights: path_of("weights")?,
            calib: path_of("calib")?,
            eval: path_of("eval")?,
            vit_logits: path_of("vit_logits")?,
            collect_acts: path_of("collect_acts")?,
            ln_tune_step: path_of("ln_tune_step")?,
            beacon_layer,
        };

        // all referenced files must exist
        for p in [
            &manifest.weights,
            &manifest.calib,
            &manifest.eval,
            &manifest.vit_logits,
            &manifest.collect_acts,
            &manifest.ln_tune_step,
        ] {
            if !p.exists() {
                bail!("artifact {p:?} missing — re-run `make artifacts`");
            }
        }
        Ok(Artifacts { dir: dir.to_path_buf(), manifest })
    }

    /// HLO path for the Beacon kernel artifact covering an N×N' layer.
    pub fn beacon_layer_hlo(&self, n: usize, np: usize) -> Result<&Path> {
        let key = format!("{n}x{np}");
        self.manifest
            .beacon_layer
            .get(&key)
            .map(|p| p.as_path())
            .with_context(|| format!("no beacon_layer artifact for shape {key}"))
    }
}

fn req_str(v: &Value, k: &str) -> Result<String> {
    Ok(v.at(&[k]).as_str().with_context(|| format!("config.{k}"))?.to_string())
}

fn req_usize(v: &Value, k: &str) -> Result<usize> {
    v.at(&[k]).as_usize().with_context(|| format!("config.{k}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Integration test against the real artifacts dir; skipped when the
    /// AOT bundle hasn't been built (e.g. bare `cargo test` in CI without
    /// `make artifacts`).
    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest__tiny-sim.json").exists().then_some(d)
    }

    #[test]
    fn loads_and_cross_checks_real_manifest() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let a = Artifacts::load(&dir, "tiny-sim").unwrap();
        assert_eq!(a.manifest.cfg.d_model, 64);
        assert_eq!(a.manifest.quantizable.len(), 16);
        assert!(a.beacon_layer_hlo(64, 192).is_ok());
        assert!(a.beacon_layer_hlo(63, 1).is_err());
    }

    #[test]
    fn missing_manifest_is_actionable() {
        let e = Artifacts::load(Path::new("/nonexistent"), "tiny-sim")
            .unwrap_err()
            .to_string();
        assert!(e.contains("make artifacts"), "{e}");
    }
}
