//! Quantization grids (paper §1): the unscaled symmetric mid-rise alphabet
//! A_b used by Beacon, the ternary "1.58-bit" and 6-level "2.58-bit"
//! grids, and the level counts for the asymmetric min-max baselines.
//! Mirror of `python/compile/common.py::alphabet`.

/// Supported bit widths. Fractional widths name non-power-of-two level
/// counts: 1.58 = log2(3), 2.58 = log2(6).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BitWidth(pub f64);

impl BitWidth {
    pub const B158: BitWidth = BitWidth(1.58);
    pub const B2: BitWidth = BitWidth(2.0);
    pub const B258: BitWidth = BitWidth(2.58);
    pub const B3: BitWidth = BitWidth(3.0);
    pub const B4: BitWidth = BitWidth(4.0);

    pub const ALL: [BitWidth; 5] = [
        Self::B158,
        Self::B2,
        Self::B258,
        Self::B3,
        Self::B4,
    ];

    pub fn parse(s: &str) -> Option<BitWidth> {
        let v: f64 = s.parse().ok()?;
        let known = [1.58, 2.0, 2.58, 3.0, 4.0, 5.0, 6.0, 8.0];
        known
            .iter()
            .find(|k| (**k - v).abs() < 1e-9)
            .map(|k| BitWidth(*k))
    }

    pub fn label(&self) -> String {
        if (self.0 - self.0.round()).abs() < 1e-9 {
            format!("{}-bit", self.0 as i64)
        } else {
            format!("{}-bit", self.0)
        }
    }

    /// Storage bits per weight after packing (ceil of the nominal width).
    pub fn storage_bits(&self) -> u32 {
        self.0.ceil() as u32
    }
}

/// Number of grid levels for width `b`.
pub fn levels(b: BitWidth) -> usize {
    let hundredths = (b.0 * 100.0).round() as i64;
    match hundredths {
        158 => 3,
        258 => 6,
        _ => 1usize << (b.0.round() as u32),
    }
}

/// The unscaled symmetric alphabet A (ascending). Integer b ≥ 2 gives the
/// mid-rise grid {−2^{b−1}+0.5, …, −0.5, 0.5, …, 2^{b−1}−0.5}; 1.58-bit is
/// ternary {−1, 0, 1}; 2.58-bit is the 6-level half-integer grid.
pub fn alphabet(b: BitWidth) -> Vec<f64> {
    let hundredths = (b.0 * 100.0).round() as i64;
    match hundredths {
        158 => vec![-1.0, 0.0, 1.0],
        258 => vec![-2.5, -1.5, -0.5, 0.5, 1.5, 2.5],
        _ => {
            let bb = b.0.round() as u32;
            assert!(bb >= 1, "unsupported bit width {}", b.0);
            let half = 1i64 << (bb - 1);
            (0..2 * half)
                .map(|k| (-half as f64 + 0.5) + k as f64)
                .collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grids_match_python() {
        assert_eq!(alphabet(BitWidth::B158), vec![-1.0, 0.0, 1.0]);
        assert_eq!(alphabet(BitWidth::B2), vec![-1.5, -0.5, 0.5, 1.5]);
        assert_eq!(
            alphabet(BitWidth::B258),
            vec![-2.5, -1.5, -0.5, 0.5, 1.5, 2.5]
        );
        assert_eq!(alphabet(BitWidth::B3).len(), 8);
        assert_eq!(alphabet(BitWidth::B4).len(), 16);
    }

    #[test]
    fn grids_symmetric() {
        for b in BitWidth::ALL {
            let a = alphabet(b);
            let mut neg: Vec<f64> = a.iter().map(|v| -v).collect();
            neg.sort_by(|x, y| x.partial_cmp(y).unwrap());
            assert_eq!(a, neg, "{b:?}");
        }
    }

    #[test]
    fn grids_ascending() {
        for b in BitWidth::ALL {
            let a = alphabet(b);
            assert!(a.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn level_counts() {
        assert_eq!(levels(BitWidth::B158), 3);
        assert_eq!(levels(BitWidth::B2), 4);
        assert_eq!(levels(BitWidth::B258), 6);
        assert_eq!(levels(BitWidth::B3), 8);
        assert_eq!(levels(BitWidth::B4), 16);
    }

    #[test]
    fn parse_and_label() {
        assert_eq!(BitWidth::parse("2").unwrap().0, 2.0);
        assert_eq!(BitWidth::parse("1.58").unwrap().0, 1.58);
        assert!(BitWidth::parse("7.3").is_none());
        assert_eq!(BitWidth::B2.label(), "2-bit");
        assert_eq!(BitWidth::B158.label(), "1.58-bit");
    }

    #[test]
    fn storage_bits_ceil() {
        assert_eq!(BitWidth::B158.storage_bits(), 2);
        assert_eq!(BitWidth::B258.storage_bits(), 3);
        assert_eq!(BitWidth::B4.storage_bits(), 4);
    }
}
