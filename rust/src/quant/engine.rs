//! The method-agnostic quantization engine: the [`Quantizer`] trait that
//! every PTQ algorithm implements, the per-layer work description
//! ([`LayerCtx`]) and result ([`LayerQuant`]), construction of boxed
//! quantizers from a [`QuantConfig`] (`Method::quantizer`), and the
//! layer/channel scheduler that splits one thread budget across the two
//! independent axes of the problem.
//!
//! Beacon's key structural property — the scale is recovered *after*
//! quantization, per channel — makes every channel an independent unit of
//! work, and (without error-correction recapture) every layer too. The
//! system around the algorithms (pipeline, recapture, metrics, serving)
//! talks only to `dyn Quantizer`, so adding a method, mixing precisions,
//! or selecting methods per layer never touches the coordinator again.
//!
//! Determinism contract: all fan-out goes through
//! [`crate::util::pool::par_map_indexed`], which gathers results in index
//! order and runs each item exactly once — the output is bit-identical to
//! the serial path at any thread count.

use anyhow::Result;

use crate::config::{Method, QuantConfig};
use crate::linalg::Matrix;
use crate::util::pool;

use super::alphabet::{alphabet, levels, BitWidth};
use super::beacon::{beacon_layer, beacon_layer_scenario, BeaconOpts};
use super::comq::{comq_layer_scenario, comq_layer_threads};
use super::gptq::gptq_layer;
use super::rtn::{minmax_scale, nearest_level, rtn_channel_scenario};
use super::scenario::{assemble_layer, Scenario};

/// Result of quantizing a full layer, for every method.
///
/// The reconstruction model is `W_q ≈ Q·Diag(s) + 1·offsetᵀ`: column j of
/// `dequant` is `scales[j]·codes[j] + offsets[j]`. For Beacon the identity
/// is exact by construction (the scale is the Prop 2.1 least-squares
/// coefficient). For the min-max grid methods (RTN/GPTQ/COMQ) `codes` are
/// the integer grid indices and `scales`/`offsets` the per-channel grid
/// `(c, c·z)`; `dequant` is the authoritative output (computed as
/// `c·(k + z)` inside the kernels) and the factored form reproduces it up
/// to one floating-point rounding.
#[derive(Debug, Clone)]
pub struct LayerQuant {
    /// q values per channel (column-major: `codes[j]` is channel j's codes).
    pub codes: Vec<Vec<f64>>,
    /// per-channel scale (group 0's scale under a grouped scenario)
    pub scales: Vec<f64>,
    /// per-channel additive offset row (zero unless centering / min-max z;
    /// group 0's offset under a grouped scenario)
    pub offsets: Vec<f64>,
    /// dequantized weights, shape of W — always authoritative
    pub dequant: Matrix,
    /// present iff the layer was quantized under a non-dense scenario
    /// (grouped scales and/or an outlier sidecar); `None` is the
    /// historical per-channel dense result
    pub grouped: Option<GroupedMeta>,
}

/// Per-channel scenario metadata riding on a [`LayerQuant`]: the full
/// per-group `(scale, offset)` tables and the exact-value outlier
/// sidecar. For non-outlier element `i` of channel `j`,
/// `dequant[(i,j)] = groups[j][i / group_size].0 · codes[j][i] +
/// groups[j][i / group_size].1`; outlier slots carry the exact weight in
/// `dequant` (their codes are on-grid dummies).
#[derive(Debug, Clone, PartialEq)]
pub struct GroupedMeta {
    /// elements per group (0 = one group spanning the channel)
    pub group_size: usize,
    /// `groups[j]` = channel j's per-group `(scale, offset)`, in order
    pub groups: Vec<Vec<(f64, f64)>>,
    /// `outliers[j]` = channel j's `(row, exact value)`, ascending rows
    pub outliers: Vec<Vec<(usize, f64)>>,
}

/// Everything a quantizer may look at for one layer.
///
/// * `x`  — FP-model activations feeding the layer (m×N)
/// * `xt` — activations from the partially quantized model (X̃); equal to
///   `x` unless the pipeline is running error-correction recapture
/// * `w`  — the layer weights (N×N'), channels = columns
/// * `threads` — resolved channel-axis thread budget (≥ 1) for this call;
///   the scheduler shrinks it when it is already fanning layers
pub struct LayerCtx<'a> {
    pub x: &'a Matrix,
    pub xt: &'a Matrix,
    pub w: &'a Matrix,
    pub threads: usize,
}

impl<'a> LayerCtx<'a> {
    /// Context for the no-error-correction case (X̃ = X).
    pub fn plain(x: &'a Matrix, w: &'a Matrix, threads: usize) -> LayerCtx<'a> {
        LayerCtx { x, xt: x, w, threads: threads.max(1) }
    }
}

/// One PTQ algorithm behind a uniform, scheduler-friendly interface.
///
/// Implementations must be pure functions of the context (no hidden
/// state), so the scheduler may invoke them concurrently on independent
/// layers whenever [`Quantizer::parallel_safe`] holds.
pub trait Quantizer: Send + Sync {
    /// Short method name ("beacon", "gptq", ...), used in labels/reports.
    fn name(&self) -> &'static str;

    /// Whether the method consumes the prefactored square form
    /// (L = UᵀX, L̃ = R from the QR) — i.e. whether an AOT kernel artifact
    /// built for that form can stand in for the native implementation.
    fn supports_prefactored(&self) -> bool {
        false
    }

    /// Whether independent layers may be quantized concurrently. Native
    /// implementations are pure and return `true`; adapters that route
    /// through a single-threaded runtime (PJRT) return `false`.
    fn parallel_safe(&self) -> bool {
        true
    }

    /// Whether the pipeline should recapture X̃ from the partially
    /// quantized model between layers (§3 error correction). Only
    /// meaningful for methods that read `ctx.xt`.
    fn uses_recapture(&self) -> bool {
        false
    }

    /// Quantize one layer.
    fn quantize_layer(&self, ctx: &LayerCtx) -> Result<LayerQuant>;
}

impl Method {
    /// The native quantizer for this method at the given bit width,
    /// configured from `qc`'s per-method options (loops, centering,
    /// error correction, damping).
    ///
    /// The width is an explicit parameter — not read from `qc.bits` — so
    /// a [`crate::config::QuantPlan`] can assign a different, already
    /// validated width to every layer. This is the single construction
    /// point the coordinator dispatches through —
    /// `coordinator/pipeline.rs` holds no per-method logic.
    pub fn quantizer(&self, bits: BitWidth, qc: &QuantConfig) -> Box<dyn Quantizer> {
        let scenario = Scenario::from_config(qc);
        match self {
            Method::Beacon => Box::new(BeaconQuantizer {
                alph: alphabet(bits),
                opts: BeaconOpts {
                    loops: qc.loops,
                    centering: qc.centering,
                    threads: 0,
                },
                error_correction: qc.error_correction,
                scenario,
            }),
            Method::Gptq => Box::new(GptqQuantizer { bits, damp: qc.gptq_damp, scenario }),
            Method::Rtn => Box::new(RtnQuantizer { bits, scenario }),
            Method::Comq => Box::new(ComqQuantizer { bits, loops: qc.loops, scenario }),
        }
    }
}

impl crate::config::LayerAssignment {
    /// The quantizer for this plan entry. Pipeline-level knobs come from
    /// the plan's base config; method/bits/opts from the assignment.
    pub fn quantizer(&self, base: &QuantConfig) -> Box<dyn Quantizer> {
        self.method.quantizer(self.bits, &self.to_config(base))
    }
}

/// Beacon (Algorithm 1) through the native Rust twin of the Pallas
/// kernel: integrated grid selection with the scale recovered after the
/// per-channel sweep; optional centering (§3).
pub struct BeaconQuantizer {
    pub alph: Vec<f64>,
    pub opts: BeaconOpts,
    pub error_correction: bool,
    pub scenario: Scenario,
}

impl Quantizer for BeaconQuantizer {
    fn name(&self) -> &'static str {
        "beacon"
    }

    fn supports_prefactored(&self) -> bool {
        true
    }

    fn uses_recapture(&self) -> bool {
        self.error_correction
    }

    fn quantize_layer(&self, ctx: &LayerCtx) -> Result<LayerQuant> {
        let opts = BeaconOpts { threads: ctx.threads, ..self.opts.clone() };
        if self.scenario.is_default() {
            Ok(beacon_layer(ctx.x, ctx.xt, ctx.w, &self.alph, &opts))
        } else {
            Ok(beacon_layer_scenario(
                ctx.x,
                ctx.xt,
                ctx.w,
                &self.alph,
                &opts,
                &self.scenario,
            ))
        }
    }
}

/// GPTQ/OPTQ baseline: row-sequential rounding with Hessian feedback on
/// the per-channel min-max grid. The row recursion couples all rows, so
/// the channel axis stays serial inside a layer (`ctx.threads` is
/// ignored); the layer axis still fans.
pub struct GptqQuantizer {
    pub bits: BitWidth,
    pub damp: f64,
    pub scenario: Scenario,
}

impl Quantizer for GptqQuantizer {
    fn name(&self) -> &'static str {
        "gptq"
    }

    fn quantize_layer(&self, ctx: &LayerCtx) -> Result<LayerQuant> {
        // plan building rejects this combination already; defense in
        // depth for direct construction
        if self.scenario.splits_channel() {
            anyhow::bail!(
                "gptq supports only the dense per-channel scenario \
                 (got group_size={}, outlier_k={})",
                self.scenario.group_size,
                self.scenario.outlier_k
            );
        }
        let dequant = gptq_layer(ctx.xt, ctx.w, self.bits, self.damp);
        Ok(minmax_layer_quant(ctx.w, dequant, self.bits))
    }
}

/// Round-to-nearest on the per-channel min-max grid.
pub struct RtnQuantizer {
    pub bits: BitWidth,
    pub scenario: Scenario,
}

impl Quantizer for RtnQuantizer {
    fn name(&self) -> &'static str {
        "rtn"
    }

    fn quantize_layer(&self, ctx: &LayerCtx) -> Result<LayerQuant> {
        let w = ctx.w;
        let (n, np) = (w.rows, w.cols);
        // Grouped / outlier-split scenario: per-group min-max grids over
        // the non-outlier members. The min-max grid is already
        // asymmetric, so the `asymmetric` flag alone keeps the dense
        // path (it changes nothing for this family).
        if self.scenario.splits_channel() {
            let w_cols = w.columns();
            let results = pool::par_map_labeled("engine.channels", np, ctx.threads, |j| {
                rtn_channel_scenario(&w_cols[j], self.bits, &self.scenario)
            });
            return Ok(assemble_layer(n, results, &self.scenario));
        }
        // One pass per channel: grid, codes and dequant together.
        // Rounding itself is all the work RTN does, so the generic
        // `minmax_layer_quant` recovery would double the layer cost;
        // dequant uses the exact `rtn_channel` expression `c·(k + z)`,
        // keeping the legacy free function bit-identical.
        let lv = levels(self.bits);
        let w_cols = w.columns();
        let cols = pool::par_map_labeled("engine.channels", np, ctx.threads, |j| {
            let wj = &w_cols[j];
            let (c, z) = minmax_scale(wj, self.bits);
            let mut codes = Vec::with_capacity(n);
            let mut dq = Vec::with_capacity(n);
            for &v in wj {
                let k = nearest_level(v, c, z, lv) as f64;
                codes.push(k);
                dq.push(c * (k + z));
            }
            (codes, dq, c, c * z)
        });
        let mut dequant = Matrix::zeros(n, np);
        let mut codes = Vec::with_capacity(np);
        let mut scales = Vec::with_capacity(np);
        let mut offsets = Vec::with_capacity(np);
        for (j, (q, dq, c, off)) in cols.into_iter().enumerate() {
            dequant.set_col(j, &dq);
            codes.push(q);
            scales.push(c);
            offsets.push(off);
        }
        Ok(LayerQuant { codes, scales, offsets, dequant, grouped: None })
    }
}

/// COMQ baseline: cyclic coordinate descent on the fixed min-max grid,
/// channels independent.
pub struct ComqQuantizer {
    pub bits: BitWidth,
    pub loops: usize,
    pub scenario: Scenario,
}

impl Quantizer for ComqQuantizer {
    fn name(&self) -> &'static str {
        "comq"
    }

    fn quantize_layer(&self, ctx: &LayerCtx) -> Result<LayerQuant> {
        if self.scenario.splits_channel() {
            return Ok(comq_layer_scenario(
                ctx.xt,
                ctx.w,
                self.bits,
                self.loops,
                ctx.threads,
                &self.scenario,
            ));
        }
        let dequant =
            comq_layer_threads(ctx.xt, ctx.w, self.bits, self.loops, ctx.threads);
        Ok(minmax_layer_quant(ctx.w, dequant, self.bits))
    }
}

/// Lift a dequantized min-max-grid layer into the factored [`LayerQuant`]
/// form: per-channel grid `(c, z)` from the *original* weights (the
/// contract all three grid methods share), integer codes recovered by
/// inverting `dq = c·(k + z)`.
///
/// The recovery is one O(N·N') sweep — negligible next to the GPTQ and
/// COMQ kernels it post-processes (Hessian/Gram work is O(N²·N') and
/// up). RTN builds its codes inline instead (see [`RtnQuantizer`]),
/// where this sweep would be as expensive as the method itself.
fn minmax_layer_quant(w: &Matrix, dequant: Matrix, bits: BitWidth) -> LayerQuant {
    let (n, np) = (w.rows, w.cols);
    let mut codes = Vec::with_capacity(np);
    let mut scales = Vec::with_capacity(np);
    let mut offsets = Vec::with_capacity(np);
    for j in 0..np {
        let col = w.col(j);
        let (c, z) = minmax_scale(&col, bits);
        let q: Vec<f64> = (0..n).map(|i| (dequant[(i, j)] / c - z).round()).collect();
        codes.push(q);
        scales.push(c);
        offsets.push(c * z);
    }
    LayerQuant { codes, scales, offsets, dequant, grouped: None }
}

// ---------------------------------------------------------------------------
// Layer/channel scheduler
// ---------------------------------------------------------------------------

/// How one thread budget is split across the two independent axes.
///
/// Invariant: `layer_threads · channel_threads ≤ max(threads, 1)` and
/// `layer_threads ≤ layers` — the outer fan runs whole layers, each of
/// which nests `channel_threads` workers into its channel sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Schedule {
    pub layer_threads: usize,
    pub channel_threads: usize,
}

/// Plan the split. `layer_parallel` is false when layers are coupled
/// (error-correction recapture) or the quantizer is not
/// [`Quantizer::parallel_safe`]; the whole budget then goes to channels.
///
/// Among splits that use the most of the budget
/// (`layer·channel ≤ threads`), the widest layer fan wins: outer-level
/// parallelism also amortizes each layer's serial sections (QR, gram,
/// column gather), which nested channel workers cannot reach. Naively
/// maximizing `layer_threads` alone strands workers when `layers` does
/// not divide `threads` (8 threads over 5 layers would run 5×1 = 5
/// workers; this picks 4×2 = 8).
pub fn plan(threads: usize, layers: usize, layer_parallel: bool) -> Schedule {
    let threads = threads.max(1);
    if !layer_parallel || layers <= 1 {
        return Schedule { layer_threads: 1, channel_threads: threads };
    }
    let mut best = Schedule { layer_threads: 1, channel_threads: threads };
    for lt in 2..=threads.min(layers) {
        let ct = threads / lt;
        if lt * ct >= best.layer_threads * best.channel_threads {
            best = Schedule { layer_threads: lt, channel_threads: ct };
        }
    }
    best
}

/// Fan `f` over `0..layers` with the planned layer-axis width, gathering
/// results in index order; the first error (in index order) propagates.
/// Each layer runs inside an `engine`-category span (`layer[i]`), so a
/// trace shows the layer fan nested under the owning phase.
pub fn run_layers<T, F>(sched: Schedule, layers: usize, f: F) -> Result<Vec<T>>
where
    T: Send,
    F: Fn(usize) -> Result<T> + Sync,
{
    pool::par_map_labeled("engine.layers", layers, sched.layer_threads, |li| {
        let _span = crate::obs::span_args("engine", || (format!("layer[{li}]"), Vec::new()));
        f(li)
    })
    .into_iter()
    .collect()
}

/// Fan a `layers × cands` probe grid over the layer axis: `f(li, ci)` is
/// invoked for every cell, candidates serially inside each layer worker
/// (they share the layer's activations/gram, so layer-major fan keeps the
/// working set hot), layers across `sched.layer_threads`. Results gather
/// as `out[li][ci]` in index order — bit-identical at any thread count,
/// like [`run_layers`]. This is the planner's probe sweep.
pub fn run_probe_grid<T, F>(
    sched: Schedule,
    layers: usize,
    cands: usize,
    f: F,
) -> Result<Vec<Vec<T>>>
where
    T: Send,
    F: Fn(usize, usize) -> Result<T> + Sync,
{
    run_layers(sched, layers, |li| {
        (0..cands).map(|ci| f(li, ci)).collect::<Result<Vec<T>>>()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Gen;

    fn case(seed: u64, m: usize, n: usize, np: usize) -> (Matrix, Matrix) {
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(seed) };
        let x = Matrix::from_vec(m, n, g.vec_normal(m * n, 1.0));
        let w = Matrix::from_vec(n, np, g.vec_normal(n * np, 0.3));
        (x, w)
    }

    fn qc(method: Method) -> QuantConfig {
        QuantConfig { method, bits: 2.0, loops: 3, ..QuantConfig::default() }
    }

    fn quantizer_of(m: Method) -> Box<dyn Quantizer> {
        let c = qc(m);
        m.quantizer(c.bit_width().unwrap(), &c)
    }

    #[test]
    fn names_and_capabilities() {
        let cfgs = [
            (Method::Beacon, "beacon", true),
            (Method::Gptq, "gptq", false),
            (Method::Rtn, "rtn", false),
            (Method::Comq, "comq", false),
        ];
        for (m, name, prefactored) in cfgs {
            let q = quantizer_of(m);
            assert_eq!(q.name(), name);
            assert_eq!(q.supports_prefactored(), prefactored);
            assert!(q.parallel_safe());
            assert!(!q.uses_recapture());
        }
        let mut c = qc(Method::Beacon);
        c.error_correction = true;
        assert!(Method::Beacon
            .quantizer(c.bit_width().unwrap(), &c)
            .uses_recapture());
    }

    #[test]
    fn factored_form_reconstructs_dequant() {
        let (x, w) = case(11, 64, 8, 5);
        for m in [Method::Beacon, Method::Gptq, Method::Rtn, Method::Comq] {
            let lq = quantizer_of(m)
                .quantize_layer(&LayerCtx::plain(&x, &w, 1))
                .unwrap();
            assert_eq!(lq.codes.len(), w.cols);
            assert_eq!(lq.scales.len(), w.cols);
            for j in 0..w.cols {
                for i in 0..w.rows {
                    let rebuilt = lq.scales[j] * lq.codes[j][i] + lq.offsets[j];
                    assert!(
                        (rebuilt - lq.dequant[(i, j)]).abs() < 1e-9,
                        "{m:?} ({i},{j}): {rebuilt} vs {}",
                        lq.dequant[(i, j)]
                    );
                }
            }
        }
    }

    #[test]
    fn schedule_plan_invariants() {
        // serial when coupled or single layer
        assert_eq!(plan(8, 16, false), Schedule { layer_threads: 1, channel_threads: 8 });
        assert_eq!(plan(8, 1, true), Schedule { layer_threads: 1, channel_threads: 8 });
        // budget never oversubscribed, both axes ≥ 1
        for threads in [1usize, 2, 3, 4, 8, 32] {
            for layers in [1usize, 2, 5, 16] {
                let s = plan(threads, layers, true);
                assert!(s.layer_threads >= 1 && s.channel_threads >= 1);
                assert!(s.layer_threads * s.channel_threads <= threads.max(1));
                assert!(s.layer_threads <= layers.max(1));
            }
        }
        assert_eq!(plan(0, 4, true), Schedule { layer_threads: 1, channel_threads: 1 });
        // non-divisible splits must not strand budget: 8 over 5 layers
        // runs 4×2 = 8 workers, not 5×1 = 5
        assert_eq!(plan(8, 5, true), Schedule { layer_threads: 4, channel_threads: 2 });
        // …and the full budget still goes wide when layers allow it
        assert_eq!(plan(8, 16, true), Schedule { layer_threads: 8, channel_threads: 1 });
        assert_eq!(plan(15, 8, true), Schedule { layer_threads: 5, channel_threads: 3 });
    }

    #[test]
    fn run_probe_grid_gathers_cells_in_order() {
        let sched = plan(4, 5, true);
        let grid = run_probe_grid(sched, 5, 3, |li, ci| Ok(li * 10 + ci)).unwrap();
        assert_eq!(grid.len(), 5);
        for (li, row) in grid.iter().enumerate() {
            assert_eq!(row, &vec![li * 10, li * 10 + 1, li * 10 + 2]);
        }
        let err = run_probe_grid(sched, 5, 3, |li, ci| {
            if li == 2 && ci == 1 {
                Err(anyhow::anyhow!("probe ({li},{ci}) failed"))
            } else {
                Ok(0usize)
            }
        });
        assert!(err.unwrap_err().to_string().contains("(2,1)"));
    }

    #[test]
    fn run_layers_gathers_in_order_and_propagates_errors() {
        let sched = plan(4, 6, true);
        let ok: Vec<usize> =
            run_layers(sched, 6, |i| Ok(i * 10)).unwrap();
        assert_eq!(ok, vec![0, 10, 20, 30, 40, 50]);
        let err = run_layers(sched, 6, |i| {
            if i == 3 {
                Err(anyhow::anyhow!("layer {i} failed"))
            } else {
                Ok(i)
            }
        });
        assert!(err.unwrap_err().to_string().contains("layer 3"));
    }
}
