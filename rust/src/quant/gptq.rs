//! GPTQ/OPTQ baseline (Frantar et al. 2022): sequential row rounding on an
//! asymmetric per-channel min-max grid with Hessian-driven error feedback.
//!
//! Exact (unblocked) formulation, matching
//! `python/compile/kernels/ref.py::gptq_layer`:
//!   H = XᵀX + λI,  Hinv = H⁻¹,  Uc = chol(Hinv)ᵀ (upper, Hinv = UcᵀUc);
//!   for each row t: round, err = (w − q)/Uc[t,t],
//!   W[t+1:,:] −= Uc[t, t+1:] ⊗ err.
//!
//! The row recursion couples every channel within a layer, so GPTQ stays
//! serial on the channel axis; the scheduler still fans independent
//! *layers* through its [`crate::quant::engine::GptqQuantizer`] wrapper,
//! constructed per layer with the bit width / damping the
//! [`crate::config::QuantPlan`] entry assigns.

use crate::linalg::qr::spd_inverse;
use crate::linalg::{cholesky_lower, Matrix};

use super::alphabet::{levels, BitWidth};
use super::rtn::{minmax_scale, nearest_level};

/// Quantize a layer with GPTQ. `x` is m×N calibration input, `w` is N×N'.
/// Returns the dequantized weights.
pub fn gptq_layer(x: &Matrix, w: &Matrix, bits: BitWidth, damp: f64) -> Matrix {
    let (n, np) = (w.rows, w.cols);
    let mut h = x.gram();
    let mean_diag: f64 = (0..n).map(|i| h[(i, i)]).sum::<f64>() / n as f64;
    let lam = damp * mean_diag + 1e-10;
    for i in 0..n {
        h[(i, i)] += lam;
    }
    let hinv = spd_inverse(&h);
    let uc = cholesky_lower(&hinv).transpose(); // upper, Hinv = UcᵀUc

    // grids fixed up front from the original weights (per channel)
    let lv = levels(bits);
    let mut scales = vec![0.0f64; np];
    let mut zeros = vec![0.0f64; np];
    for j in 0..np {
        let col = w.col(j);
        let (c, z) = minmax_scale(&col, bits);
        scales[j] = c;
        zeros[j] = z;
    }

    let mut work = w.clone();
    let mut out = Matrix::zeros(n, np);
    let mut err = vec![0.0f64; np];
    for t in 0..n {
        let dt = uc[(t, t)];
        {
            let row = work.row(t);
            let orow = out.row_mut(t);
            for j in 0..np {
                let q = scales[j]
                    * (nearest_level(row[j], scales[j], zeros[j], lv) as f64
                        + zeros[j]);
                orow[j] = q;
                err[j] = (row[j] - q) / dt;
            }
        }
        // feedback onto the not-yet-quantized rows
        for i in t + 1..n {
            let u_ti = uc[(t, i)];
            if u_ti == 0.0 {
                continue;
            }
            let wrow = work.row_mut(i);
            for j in 0..np {
                wrow[j] -= u_ti * err[j];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::metrics::layer_recon_error;
    use crate::quant::rtn::rtn_layer;
    use crate::util::prop::Gen;

    fn case(g: &mut Gen, m: usize, n: usize, np: usize) -> (Matrix, Matrix) {
        let x = Matrix::from_vec(m, n, g.vec_normal(m * n, 1.0));
        let w = Matrix::from_vec(n, np, g.vec_normal(n * np, 0.25));
        (x, w)
    }

    #[test]
    fn beats_rtn_in_recon_error_on_average() {
        // GPTQ's greedy error feedback is not instance-wise dominant, but
        // it must win in aggregate (and by a clear margin at 2-bit).
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(0xBEAC0) };
        for bits in [BitWidth::B2, BitWidth::B3] {
            let mut sum_rtn = 0.0;
            let mut sum_gq = 0.0;
            let mut wins = 0;
            let trials = 12;
            for _ in 0..trials {
                let (x, w) = case(&mut g, 96, 12, 6);
                let e_rtn = layer_recon_error(&x, &w, &rtn_layer(&w, bits));
                let e_gq =
                    layer_recon_error(&x, &w, &gptq_layer(&x, &w, bits, 0.01));
                sum_rtn += e_rtn;
                sum_gq += e_gq;
                if e_gq <= e_rtn {
                    wins += 1;
                }
            }
            assert!(
                sum_gq < sum_rtn,
                "{bits:?}: mean gptq {sum_gq} >= mean rtn {sum_rtn}"
            );
            assert!(wins * 3 >= trials * 2, "{bits:?}: gptq won only {wins}/{trials}");
        }
    }

    #[test]
    fn outputs_on_per_channel_grid() {
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(1) };
        let (x, w) = case(&mut g, 64, 10, 4);
        let q = gptq_layer(&x, &w, BitWidth::B2, 0.01);
        for j in 0..4 {
            let mut uniq: Vec<i64> =
                (0..10).map(|i| (q[(i, j)] * 1e9).round() as i64).collect();
            uniq.sort_unstable();
            uniq.dedup();
            assert!(uniq.len() <= 4, "channel {j}: {} levels", uniq.len());
        }
    }

    #[test]
    fn first_row_is_plain_rtn() {
        // before any feedback, row 0 must round exactly like RTN
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(2) };
        let (x, w) = case(&mut g, 64, 8, 3);
        let q = gptq_layer(&x, &w, BitWidth::B3, 0.01);
        let rtn = rtn_layer(&w, BitWidth::B3);
        for j in 0..3 {
            assert!((q[(0, j)] - rtn[(0, j)]).abs() < 1e-9);
        }
    }

    #[test]
    fn higher_bits_lower_error() {
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(3) };
        let (x, w) = case(&mut g, 96, 12, 5);
        let e2 = layer_recon_error(&x, &w, &gptq_layer(&x, &w, BitWidth::B2, 0.01));
        let e4 = layer_recon_error(&x, &w, &gptq_layer(&x, &w, BitWidth::B4, 0.01));
        assert!(e4 < e2);
    }

    #[test]
    fn damping_keeps_it_stable_on_rank_deficient_input() {
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(4) };
        // m < n would make XᵀX singular without damping
        let x = Matrix::from_vec(6, 12, g.vec_normal(72, 1.0));
        let w = Matrix::from_vec(12, 3, g.vec_normal(36, 0.3));
        let q = gptq_layer(&x, &w, BitWidth::B2, 0.05);
        assert!(q.data.iter().all(|v| v.is_finite()));
    }
}
