//! Layer-reconstruction metrics (paper eq. 1) and summary statistics used
//! by the ablation reports.

use crate::linalg::Matrix;

/// ‖XW − XQ‖_F / ‖XW‖_F — relative layer reconstruction error.
pub fn layer_recon_error(x: &Matrix, w: &Matrix, q: &Matrix) -> f64 {
    let num = x.matmul(&w.sub(q)).frob_norm();
    let den = x.matmul(w).frob_norm() + 1e-12;
    num / den
}

/// Same metric via the gram matrix G = XᵀX:
/// ‖XD‖_F² = tr(DᵀGD). Turns two m×N×N' products into one m×N² gram
/// (computed once per layer by the pipeline and shared with the planner
/// probes) plus N²×N' trace terms — the §Perf fast path for per-layer
/// error reporting.
///
/// The guard epsilon is applied post-sqrt on the denominator norm —
/// exactly where [`layer_recon_error`] applies its `1e-12` — so the two
/// variants agree even for degenerate (near-zero) activations. The trace
/// terms are clamped at zero first: they are mathematically non-negative
/// but can round slightly below zero for tiny inputs.
pub fn layer_recon_error_gram(g: &Matrix, w: &Matrix, q: &Matrix) -> f64 {
    let d = w.sub(q);
    let num = quad_trace(g, &d).max(0.0).sqrt();
    let den = quad_trace(g, w).max(0.0).sqrt() + 1e-12;
    num / den
}

/// tr(AᵀGA) = Σ_j a_jᵀ G a_j.
fn quad_trace(g: &Matrix, a: &Matrix) -> f64 {
    let mut total = 0.0;
    for j in 0..a.cols {
        let col = a.col(j);
        let gv = g.matvec(&col);
        total += crate::linalg::matrix::dot(&col, &gv);
    }
    total
}

/// ‖XW − X̃Q‖_F / ‖XW‖_F — the error-corrected objective (§3).
pub fn layer_recon_error_ec(x: &Matrix, xt: &Matrix, w: &Matrix, q: &Matrix) -> f64 {
    let num = x.matmul(w).sub(&xt.matmul(q)).frob_norm();
    let den = x.matmul(w).frob_norm() + 1e-12;
    num / den
}

/// Mean and max absolute weight error (grid-only view, no activations).
pub fn weight_error(w: &Matrix, q: &Matrix) -> (f64, f64) {
    let mut sum = 0.0;
    let mut max = 0.0f64;
    for (a, b) in w.data.iter().zip(&q.data) {
        let e = (a - b).abs();
        sum += e;
        max = max.max(e);
    }
    (sum / w.data.len() as f64, max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Gen;

    #[test]
    fn zero_error_for_exact() {
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(0) };
        let x = Matrix::from_vec(16, 4, g.vec_normal(64, 1.0));
        let w = Matrix::from_vec(4, 3, g.vec_normal(12, 1.0));
        assert!(layer_recon_error(&x, &w, &w) < 1e-12);
        assert_eq!(weight_error(&w, &w), (0.0, 0.0));
    }

    #[test]
    fn scales_with_perturbation() {
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(1) };
        let x = Matrix::from_vec(16, 4, g.vec_normal(64, 1.0));
        let w = Matrix::from_vec(4, 3, g.vec_normal(12, 1.0));
        let mut q1 = w.clone();
        let mut q2 = w.clone();
        for v in q1.data.iter_mut() {
            *v += 0.01;
        }
        for v in q2.data.iter_mut() {
            *v += 0.1;
        }
        assert!(layer_recon_error(&x, &w, &q1) < layer_recon_error(&x, &w, &q2));
    }

    #[test]
    fn gram_variant_matches_direct() {
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(3) };
        let x = Matrix::from_vec(32, 6, g.vec_normal(192, 1.0));
        let w = Matrix::from_vec(6, 4, g.vec_normal(24, 1.0));
        let mut q = w.clone();
        for v in q.data.iter_mut() {
            *v += 0.07 * g.normal();
        }
        let direct = layer_recon_error(&x, &w, &q);
        let viagram = layer_recon_error_gram(&x.gram(), &w, &q);
        assert!((direct - viagram).abs() < 1e-10, "{direct} vs {viagram}");
    }

    #[test]
    fn gram_variant_matches_direct_for_degenerate_activations() {
        // the old gram variant added its epsilon pre-sqrt (1e-24 on the
        // squared norm), so near-zero activations made the two metrics
        // diverge; both now guard post-sqrt with the same 1e-12
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(5) };
        let x = Matrix::from_vec(16, 4, g.vec_normal(64, 1e-13));
        let w = Matrix::from_vec(4, 3, g.vec_normal(12, 1.0));
        let mut q = w.clone();
        for v in q.data.iter_mut() {
            *v += 0.1;
        }
        let direct = layer_recon_error(&x, &w, &q);
        let viagram = layer_recon_error_gram(&x.gram(), &w, &q);
        assert!(
            (direct - viagram).abs() <= 1e-6 * direct.max(1.0),
            "{direct} vs {viagram}"
        );
    }

    #[test]
    fn ec_matches_plain_when_inputs_equal() {
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(2) };
        let x = Matrix::from_vec(16, 4, g.vec_normal(64, 1.0));
        let w = Matrix::from_vec(4, 3, g.vec_normal(12, 1.0));
        let mut q = w.clone();
        for v in q.data.iter_mut() {
            *v += 0.05;
        }
        let a = layer_recon_error(&x, &w, &q);
        let b = layer_recon_error_ec(&x, &x, &w, &q);
        assert!((a - b).abs() < 1e-12);
    }
}
