//! The quantization algorithms: Beacon (the paper's contribution, with
//! error correction + centering), the baselines it is evaluated against
//! (GPTQ, RTN, COMQ), integer bit-packing for deployment, and the
//! layer-reconstruction metrics of eq. (1).
//!
//! All algorithms run in f64 internally (matching the numpy oracles in
//! `python/compile/kernels/ref.py`) and share the column-gathered layout
//! produced by [`crate::linalg::Matrix::columns`].

pub mod alphabet;
pub mod beacon;
pub mod comq;
pub mod gptq;
pub mod metrics;
pub mod packing;
pub mod rtn;

pub use alphabet::{alphabet, levels, BitWidth};
pub use beacon::{beacon_channel, beacon_layer, BeaconOpts};
pub use comq::comq_layer;
pub use gptq::gptq_layer;
pub use metrics::layer_recon_error;
pub use rtn::{minmax_scale, rtn_channel, rtn_layer};
