//! The quantization algorithms: Beacon (the paper's contribution, with
//! error correction + centering), the baselines it is evaluated against
//! (GPTQ, RTN, COMQ), integer bit-packing for deployment, and the
//! layer-reconstruction metrics of eq. (1).
//!
//! All algorithms run in f64 internally (matching the numpy oracles in
//! `python/compile/kernels/ref.py`) and share the column-gathered layout
//! produced by [`crate::linalg::Matrix::columns`].
//!
//! # The `Quantizer` trait and the engine
//!
//! Every method is exposed twice: as a free function with its natural
//! signature (`beacon_layer`, `gptq_layer`, `rtn_layer`, `comq_layer` —
//! the tested kernels), and as an [`engine::Quantizer`] implementation
//! that adapts the kernel to the uniform per-layer interface
//!
//! ```text
//!   Method::quantizer(BitWidth, &QuantConfig) -> Box<dyn Quantizer>
//!   LayerAssignment::quantizer(&base)        -> Box<dyn Quantizer>   // plan entry
//!   Quantizer::quantize_layer(&LayerCtx { x, xt, w, threads }) -> LayerQuant
//! ```
//!
//! The bit width is an explicit parameter so a
//! [`crate::config::QuantPlan`] can assign a different width (and
//! method) to every layer; flat configs validate `bits` once and pass it
//! through.
//!
//! [`engine::LayerCtx`] carries the FP activations `x`, the (possibly
//! recaptured) activations `xt`, the weights, and the resolved thread
//! budget; [`engine::LayerQuant`] is the universal factored result
//! `W_q ≈ Q·Diag(s) + 1·offsetᵀ`. The coordinator dispatches only
//! through the trait — it contains no per-method logic.
//!
//! # Threading model
//!
//! Two independent axes of parallelism exist: channels within a layer
//! (Beacon/RTN/COMQ — per-channel PTQ with the scale recovered after
//! quantization makes each channel a closed unit of work) and whole
//! layers (whenever error-correction recapture is off). One budget —
//! `QuantConfig::threads`, `--threads`, or the `BEACON_THREADS` env var
//! (0 = auto = core count) — is split across both axes by
//! [`engine::plan`]; all fan-out funnels through
//! [`crate::util::pool::par_map_indexed`], which gathers results in index
//! order, so every output is bit-identical to the serial run at any
//! thread count.

pub mod alphabet;
pub mod beacon;
pub mod comq;
pub mod engine;
pub mod gptq;
pub mod metrics;
pub mod packing;
pub mod rtn;
pub mod scenario;

pub use alphabet::{alphabet, levels, BitWidth};
pub use beacon::{beacon_channel, beacon_layer, BeaconOpts};
pub use comq::{comq_layer, comq_layer_threads};
pub use engine::{GroupedMeta, LayerCtx, LayerQuant, Quantizer};
pub use gptq::gptq_layer;
pub use metrics::layer_recon_error;
pub use rtn::{minmax_scale, rtn_channel, rtn_layer, rtn_layer_threads};
pub use scenario::Scenario;
