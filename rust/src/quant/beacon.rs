//! Beacon (Algorithm 1): per-channel PTQ on the unscaled symmetric grid
//! with the scale recovered *after* quantization from the geometry of the
//! problem — `c = ⟨Lw, L̃q⟩ / ‖L̃q‖²` (Prop 2.1).
//!
//! This is the native Rust twin of the Pallas kernel
//! (`python/compile/kernels/beacon.py`); both follow the oracle
//! `python/compile/kernels/ref.py` including the tie-breaking contract:
//! candidates scanned in ascending order, strict `>` replacement,
//! zero-denominator candidates score −inf, and the degenerate u = 0 case
//! picks the alphabet element nearest the least-squares coefficient.
//!
//! Under the plan API the pipeline constructs one
//! [`crate::quant::engine::BeaconQuantizer`] per layer from its
//! [`crate::config::LayerAssignment`], so the alphabet (bit width) and
//! sweep count may differ layer to layer; the kernels below are pure in
//! their arguments and need no changes to serve mixed plans.
//!
//! Complexity per channel: the 5-scalar expansion turns each coordinate
//! update into O(N) dot products + O(|A|) candidate scoring, so a full
//! sweep is O(N²); `lt` being upper-triangular (it is R from the QR) cuts
//! the dot products to the leading `t+1` entries.

use crate::linalg::matrix::{axpy, dot};
use crate::linalg::{qr_factor, Matrix};

use super::scenario::{assemble_layer, split_outliers, ChannelQuant, Scenario};

pub const EPS: f64 = 1e-12;

#[derive(Debug, Clone)]
pub struct BeaconOpts {
    /// K — number of cyclic refinement sweeps after the greedy pass.
    pub loops: usize,
    /// Asymmetric quantization via the centering trick (§3).
    pub centering: bool,
    /// Channel-sweep thread budget; 0 = auto
    /// ([`crate::util::pool::resolve_threads`]). Any value yields
    /// bit-identical output — channels are gathered in index order.
    pub threads: usize,
}

impl Default for BeaconOpts {
    fn default() -> Self {
        BeaconOpts { loops: 4, centering: false, threads: 0 }
    }
}

/// argmax_{p ∈ A} cos∠(y, u + col·p) given the 5 scalars
/// a = ⟨y,u⟩, b = ⟨y,col⟩, cc = ‖u‖², d = ⟨u,col⟩, e = ‖col‖².
/// (The sweep maintains a/cc incrementally and precomputes b/e per
/// column — §Perf; this is the pure scoring rule both backends share.)
#[inline]
fn argmax_scored(a: f64, b: f64, cc: f64, d: f64, e: f64, alph: &[f64]) -> f64 {
    if cc <= EPS {
        // degenerate u = 0: all same-sign candidates tie on cosine; pick
        // nearest to the least-squares coefficient b/e (shared contract
        // with ref.py / the Pallas kernel), excluding p with p²e ≈ 0.
        let ls = if e > EPS { b / e } else { 0.0 };
        let mut best_p = alph[0];
        let mut best_d = f64::INFINITY;
        for &p in alph {
            let dist = if p * p * e > EPS { (p - ls).abs() } else { f64::INFINITY };
            if dist < best_d {
                best_d = dist;
                best_p = p;
            }
        }
        return best_p;
    }

    let mut best_p = alph[0];
    let mut best_s = f64::NEG_INFINITY;
    for &p in alph {
        let den2 = cc + 2.0 * p * d + p * p * e;
        let s = if den2 <= EPS {
            f64::NEG_INFINITY
        } else {
            (a + p * b) / den2.sqrt()
        };
        if s > best_s {
            best_s = s;
            best_p = p;
        }
    }
    best_p
}

/// Quantize one channel. `l_cols`/`lt_cols` are the column-gathered square
/// factors (L = UᵀX, L̃ = R); `lt_nnz[t]` is the active-prefix length of
/// L̃'s column t (t+1 for upper-triangular R, N otherwise). Returns
/// (q ∈ A^N, scale c).
pub fn beacon_channel(
    l_cols: &[Vec<f64>],
    lt_cols: &[Vec<f64>],
    lt_nnz: &[usize],
    w: &[f64],
    alph: &[f64],
    loops: usize,
) -> (Vec<f64>, f64) {
    let n = w.len();
    let dim = l_cols[0].len();
    let mut q = vec![0.0f64; n];
    let mut u = vec![0.0f64; dim]; // running L̃ q
    let mut y = vec![0.0f64; dim]; // running L_{≤t} w_{≤t}

    // ‖L̃_t‖² is loop-invariant: precompute per column (§Perf).
    let e_col: Vec<f64> = (0..n)
        .map(|t| {
            let col = &lt_cols[t][..lt_nnz[t]];
            dot(col, col)
        })
        .collect();

    // a = ⟨y,u⟩ and cc = ‖u‖² are maintained incrementally across the
    // rank-1 updates of y and u (exact update formulas, no re-dots).
    let mut a = 0.0f64;
    let mut cc = 0.0f64;

    // --- greedy path-following init (ℓ = 0) -------------------------------
    for t in 0..n {
        let nnz = lt_nnz[t];
        let colt = &lt_cols[t][..nnz];
        // y += w_t·L_t  ⇒  a += w_t·⟨L_t, u⟩
        if w[t] != 0.0 {
            a += w[t] * dot(&l_cols[t], &u);
            axpy(w[t], &l_cols[t], &mut y);
        }
        let b = dot(&y[..nnz], colt);
        let d = dot(&u[..nnz], colt);
        let p = argmax_scored(a, b, cc, d, e_col[t], alph);
        q[t] = p;
        if p != 0.0 {
            // u += p·L̃_t ⇒ a += p·b, cc += 2p·d + p²e
            a += p * b;
            cc += 2.0 * p * d + p * p * e_col[t];
            axpy(p, colt, &mut u[..nnz]);
        }
    }

    // --- K cyclic refinement sweeps (ℓ = 1..loops) -------------------------
    // y is now fixed, so b_t = ⟨y, L̃_t⟩ is sweep-invariant: precompute.
    let b_col: Vec<f64> = (0..n)
        .map(|t| dot(&y[..lt_nnz[t]], &lt_cols[t][..lt_nnz[t]]))
        .collect();
    for _ in 0..loops {
        for t in 0..n {
            let nnz = lt_nnz[t];
            let colt = &lt_cols[t][..nnz];
            let e = e_col[t];
            let b = b_col[t];
            // d before removal: the one dot product per coordinate
            let d_full = dot(&u[..nnz], colt);
            let qt = q[t];
            let (d, a_min, cc_min) = if qt != 0.0 {
                // remove q_t·L̃_t from u (scalars exactly updated)
                (
                    d_full - qt * e,
                    a - qt * b,
                    cc - 2.0 * qt * d_full + qt * qt * e,
                )
            } else {
                (d_full, a, cc)
            };
            let p = argmax_scored(a_min, b, cc_min.max(0.0), d, e, alph);
            if p != qt {
                // u += (p − q_t)·L̃_t
                axpy(p - qt, colt, &mut u[..nnz]);
                q[t] = p;
            }
            a = a_min + p * b;
            cc = cc_min + 2.0 * p * d + p * p * e;
        }
    }

    // --- integrated scale (Prop 2.1) ---------------------------------------
    // final re-dots (not the drifted accumulators) for an exact scale
    let den = dot(&u, &u);
    let c = if den > EPS { dot(&y, &u) / den } else { 0.0 };
    (q, c)
}

/// cos∠(Lw, L̃q) — the objective of Prop 3.1.
pub fn beacon_objective(l: &Matrix, lt: &Matrix, w: &[f64], q: &[f64]) -> f64 {
    let y = l.matvec(w);
    let u = lt.matvec(q);
    let ny = dot(&y, &y).sqrt();
    let nu = dot(&u, &u).sqrt();
    if ny <= EPS || nu <= EPS {
        return 0.0;
    }
    dot(&y, &u) / (ny * nu)
}

// The per-layer result type now lives with the method-agnostic engine;
// re-exported here so `quant::beacon::LayerQuant` keeps resolving.
pub use super::engine::LayerQuant;

/// Quantize a whole layer against calibration inputs.
///
/// * `x`  — FP-model activations (m×N)
/// * `xt` — partially-quantized-model activations; pass `x` again for the
///   no-error-correction variant
/// * `w`  — layer weights (N×N'), channels = columns
pub fn beacon_layer(
    x: &Matrix,
    xt: &Matrix,
    w: &Matrix,
    alph: &[f64],
    opts: &BeaconOpts,
) -> LayerQuant {
    let f = qr_factor(xt, x);
    beacon_layer_prefactored(&f.l, &f.r, x, xt, w, alph, opts)
}

/// Same as [`beacon_layer`] but with the square factors already computed
/// (the coordinator reuses one QR across method variants).
pub fn beacon_layer_prefactored(
    l: &Matrix,
    r: &Matrix,
    x: &Matrix,
    xt: &Matrix,
    w: &Matrix,
    alph: &[f64],
    opts: &BeaconOpts,
) -> LayerQuant {
    let (n, np) = (w.rows, w.cols);

    // centering: quantize Ŵ = W − 1·z_Wᵀ, restore with corrected mean
    let z_w: Vec<f64> = (0..np)
        .map(|j| (0..n).map(|i| w[(i, j)]).sum::<f64>() / n as f64)
        .collect();

    let l_cols = l.columns();
    let lt_cols = r.columns();
    // R is upper triangular: column t has t+1 leading nonzeros
    let lt_nnz: Vec<usize> = (0..n).map(|t| (t + 1).min(n)).collect();

    let w_cols = w.columns();
    let nthreads = crate::util::pool::resolve_threads(opts.threads);
    let results = crate::util::pool::par_map_labeled("engine.channels", np, nthreads, |j| {
        let wj: Vec<f64> = if opts.centering {
            w_cols[j].iter().map(|v| v - z_w[j]).collect()
        } else {
            w_cols[j].clone()
        };
        beacon_channel(&l_cols, &lt_cols, &lt_nnz, &wj, alph, opts.loops)
    });

    // corrected mean z_Q = (⟨X̃1, X1⟩ / ‖X̃1‖²)·z_W  (§3 centering)
    let offsets: Vec<f64> = if opts.centering {
        let ones = vec![1.0f64; n];
        let x1 = x.matvec(&ones);
        let xt1 = xt.matvec(&ones);
        let den = dot(&xt1, &xt1);
        let z_scale = if den > EPS { dot(&x1, &xt1) / den } else { 1.0 };
        z_w.iter().map(|z| z_scale * z).collect()
    } else {
        vec![0.0; np]
    };

    let mut dequant = Matrix::zeros(n, np);
    let mut codes = Vec::with_capacity(np);
    let mut scales = Vec::with_capacity(np);
    for (j, (q, c)) in results.into_iter().enumerate() {
        for i in 0..n {
            dequant[(i, j)] = c * q[i] + offsets[j];
        }
        codes.push(q);
        scales.push(c);
    }
    LayerQuant { codes, scales, offsets, dequant, grouped: None }
}

/// Beacon under a grouped / asymmetric / outlier-split [`Scenario`].
///
/// Per channel: the top-k magnitude weights are held exact (sidecar, with
/// the smallest-|value| alphabet element as an on-grid dummy code), then
/// each group runs [`beacon_channel`] on the channel problem *restricted
/// to its own columns* — `u = Σ_t q_t·L̃_t`, so dropping a column fixes
/// its code at 0, which makes the per-group sweep exact for the group
/// objective. Under `asymmetric` each group is centered on its own
/// non-outlier mean and restored with the §3 corrected-mean factor
/// (`off_g = z_scale·mean_g`); `centering` without `asymmetric` keeps the
/// historical whole-channel mean. With one group, no outliers and no
/// asymmetry this reproduces [`beacon_layer`] bit-for-bit.
pub fn beacon_layer_scenario(
    x: &Matrix,
    xt: &Matrix,
    w: &Matrix,
    alph: &[f64],
    opts: &BeaconOpts,
    sc: &Scenario,
) -> LayerQuant {
    let f = qr_factor(xt, x);
    let (n, np) = (w.rows, w.cols);
    let l_cols = f.l.columns();
    let lt_cols = f.r.columns();
    let bounds = sc.group_bounds(n);

    // corrected-mean restore factor (§3), shared by every group: offsets
    // enter as off·X̃1 against the target mean·X1
    let need_offsets = sc.asymmetric || opts.centering;
    let z_scale = if need_offsets {
        let ones = vec![1.0f64; n];
        let x1 = x.matvec(&ones);
        let xt1 = xt.matvec(&ones);
        let den = dot(&xt1, &xt1);
        if den > EPS {
            dot(&x1, &xt1) / den
        } else {
            1.0
        }
    } else {
        1.0
    };

    // on-grid dummy code for outlier slots: the smallest-|value| alphabet
    // element (ascending scan keeps the first on ties — deterministic)
    let dummy = alph
        .iter()
        .copied()
        .min_by(|a, b| {
            a.abs()
                .partial_cmp(&b.abs())
                .unwrap_or(std::cmp::Ordering::Equal)
        })
        .unwrap_or(0.0);

    let w_cols = w.columns();
    let nthreads = crate::util::pool::resolve_threads(opts.threads);
    let results = crate::util::pool::par_map_labeled("engine.channels", np, nthreads, |j| {
        let wj = &w_cols[j];
        let outl = split_outliers(wj, sc.outlier_k);
        let m_ch = wj.iter().sum::<f64>() / n.max(1) as f64;
        let mut codes = vec![0.0; n];
        let mut dequant = vec![0.0; n];
        let mut groups = Vec::with_capacity(bounds.len());
        for &(lo, hi) in &bounds {
            let members: Vec<usize> =
                (lo..hi).filter(|t| outl.binary_search(t).is_err()).collect();
            if members.is_empty() {
                // group fully consumed by outliers: degenerate, unused
                groups.push((1.0, 0.0));
                continue;
            }
            let mean = if sc.asymmetric {
                members.iter().map(|&t| wj[t]).sum::<f64>() / members.len() as f64
            } else if opts.centering {
                m_ch
            } else {
                0.0
            };
            let sub_l: Vec<Vec<f64>> =
                members.iter().map(|&t| l_cols[t].clone()).collect();
            let sub_lt: Vec<Vec<f64>> =
                members.iter().map(|&t| lt_cols[t].clone()).collect();
            // each column keeps its own triangular prefix length
            let sub_nnz: Vec<usize> = members.iter().map(|&t| (t + 1).min(n)).collect();
            let wg: Vec<f64> = members.iter().map(|&t| wj[t] - mean).collect();
            let (q, c) = beacon_channel(&sub_l, &sub_lt, &sub_nnz, &wg, alph, opts.loops);
            let off = z_scale * mean;
            for (k, &t) in members.iter().enumerate() {
                codes[t] = q[k];
                dequant[t] = c * q[k] + off;
            }
            groups.push((c, off));
        }
        for &t in &outl {
            codes[t] = dummy;
            dequant[t] = wj[t];
        }
        ChannelQuant {
            codes,
            groups,
            outliers: outl.iter().map(|&t| (t, wj[t])).collect(),
            dequant,
        }
    });
    assemble_layer(n, results, sc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::alphabet::{alphabet, BitWidth};
    use crate::util::prop::{prop_check, Gen};

    fn random_case(g: &mut Gen, m: usize, n: usize) -> (Matrix, Vec<f64>) {
        let x = Matrix::from_vec(m, n, g.vec_normal(m * n, 1.0));
        let w = g.vec_normal(n, 0.3);
        (x, w)
    }

    fn channel_for(x: &Matrix, w: &[f64], bits: BitWidth, loops: usize) -> (Vec<f64>, f64) {
        let f = qr_factor(x, x);
        let l_cols = f.l.columns();
        let lt_cols = f.r.columns();
        let nnz: Vec<usize> = (0..w.len()).map(|t| t + 1).collect();
        beacon_channel(&l_cols, &lt_cols, &nnz, w, &alphabet(bits), loops)
    }

    #[test]
    fn objective_monotone_in_loops() {
        // Prop 3.1
        prop_check(10, |g| {
            let (x, w) = random_case(g, 48, 10);
            let f = qr_factor(&x, &x);
            let a = alphabet(BitWidth::B2);
            let l_cols = f.l.columns();
            let lt_cols = f.r.columns();
            let nnz: Vec<usize> = (0..10).map(|t| t + 1).collect();
            let mut prev = -1.0;
            for loops in 0..5 {
                let (q, _) =
                    beacon_channel(&l_cols, &lt_cols, &nnz, &w, &a, loops);
                let obj = beacon_objective(&f.l, &f.r, &w, &q);
                if obj < prev - 1e-10 {
                    return Err(format!("objective decreased: {prev} -> {obj}"));
                }
                prev = obj;
            }
            Ok(())
        });
    }

    #[test]
    fn coordinatewise_local_optimum() {
        prop_check(8, |g| {
            let (x, w) = random_case(g, 32, 6);
            let f = qr_factor(&x, &x);
            let a = alphabet(BitWidth::B2);
            let (q, _) = channel_for(&x, &w, BitWidth::B2, 10);
            let base = beacon_objective(&f.l, &f.r, &w, &q);
            for t in 0..w.len() {
                for &p in &a {
                    let mut q2 = q.clone();
                    q2[t] = p;
                    let o = beacon_objective(&f.l, &f.r, &w, &q2);
                    if o > base + 1e-9 {
                        return Err(format!(
                            "coord {t} cand {p} improves {base} -> {o}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn scale_is_fixed_point() {
        // Corollary 2.2
        prop_check(10, |g| {
            let (x, w) = random_case(g, 40, 8);
            let f = qr_factor(&x, &x);
            let (q, c) = channel_for(&x, &w, BitWidth::B2, 3);
            let y = f.l.matvec(&w);
            let u = f.r.matvec(&q);
            let den = dot(&u, &u);
            if den <= EPS {
                return Ok(());
            }
            let expect = dot(&y, &u) / den;
            if (c - expect).abs() > 1e-9 * expect.abs().max(1.0) {
                return Err(format!("c {c} vs fixed point {expect}"));
            }
            Ok(())
        });
    }

    #[test]
    fn scale_beats_perturbations() {
        // Prop 2.1: optimal c in least squares
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(1) };
        let (x, w) = random_case(&mut g, 40, 8);
        let (q, c) = channel_for(&x, &w, BitWidth::B2, 3);
        let xw = x.matvec(&w);
        let xq = x.matvec(&q);
        let err = |cc: f64| -> f64 {
            xw.iter()
                .zip(&xq)
                .map(|(a, b)| (a - cc * b) * (a - cc * b))
                .sum::<f64>()
        };
        let e0 = err(c);
        for dc in [-0.1, -0.01, 0.01, 0.1] {
            assert!(err(c * (1.0 + dc)) >= e0 - 1e-9);
        }
    }

    #[test]
    fn codes_live_on_alphabet() {
        for bits in [BitWidth::B158, BitWidth::B2, BitWidth::B4] {
            let mut g = Gen { rng: crate::data::rng::SplitMix64::new(2) };
            let (x, w) = random_case(&mut g, 32, 9);
            let a = alphabet(bits);
            let (q, _) = channel_for(&x, &w, bits, 2);
            for v in q {
                assert!(a.iter().any(|p| (p - v).abs() < 1e-12), "{v} not in {bits:?}");
            }
        }
    }

    #[test]
    fn sign_symmetry() {
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(3) };
        let (x, w) = random_case(&mut g, 40, 8);
        let wneg: Vec<f64> = w.iter().map(|v| -v).collect();
        let (q1, c1) = channel_for(&x, &w, BitWidth::B2, 4);
        let (q2, c2) = channel_for(&x, &wneg, BitWidth::B2, 4);
        let e1: f64 = {
            let xw = x.matvec(&w);
            let xq = x.matvec(&q1);
            xw.iter().zip(&xq).map(|(a, b)| (a - c1 * b).powi(2)).sum()
        };
        let e2: f64 = {
            let xw = x.matvec(&wneg);
            let xq = x.matvec(&q2);
            xw.iter().zip(&xq).map(|(a, b)| (a - c2 * b).powi(2)).sum()
        };
        assert!((e1 - e2).abs() < 1e-8 * e1.max(1.0));
    }

    #[test]
    fn zero_weights_finite_scale() {
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(4) };
        let (x, _) = random_case(&mut g, 24, 6);
        let w = vec![0.0; 6];
        let (_, c) = channel_for(&x, &w, BitWidth::B158, 3);
        assert!(c.is_finite());
    }

    #[test]
    fn layer_centering_helps_offset_weights() {
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(5) };
        let m = 64;
        let n = 10;
        let x = Matrix::from_vec(m, n, g.vec_normal(m * n, 1.0));
        let mut w = Matrix::from_vec(n, 4, g.vec_normal(n * 4, 0.2));
        for v in w.data.iter_mut() {
            *v += 0.3; // strong common offset
        }
        let a = alphabet(BitWidth::B2);
        let plain = beacon_layer(
            &x,
            &x,
            &w,
            &a,
            &BeaconOpts { loops: 4, centering: false, ..Default::default() },
        );
        let cent = beacon_layer(
            &x,
            &x,
            &w,
            &a,
            &BeaconOpts { loops: 4, centering: true, ..Default::default() },
        );
        let err = |d: &Matrix| x.matmul(&w.sub(d)).frob_norm();
        assert!(err(&cent.dequant) < err(&plain.dequant));
    }

    #[test]
    fn scenario_asym_one_group_matches_centering_bitwise() {
        // With g=0 and k=0 the per-group mean IS the channel mean, so the
        // asymmetric scenario path must reproduce §3 centering exactly.
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(6) };
        let m = 48;
        let n = 10;
        let x = Matrix::from_vec(m, n, g.vec_normal(m * n, 1.0));
        let mut w = Matrix::from_vec(n, 3, g.vec_normal(n * 3, 0.2));
        for v in w.data.iter_mut() {
            *v += 0.3;
        }
        let a = alphabet(BitWidth::B2);
        let cent = beacon_layer(
            &x,
            &x,
            &w,
            &a,
            &BeaconOpts { loops: 4, centering: true, ..Default::default() },
        );
        let sc = Scenario { asymmetric: true, ..Scenario::default() };
        let asym = beacon_layer_scenario(
            &x,
            &x,
            &w,
            &a,
            &BeaconOpts { loops: 4, centering: false, ..Default::default() },
            &sc,
        );
        for (p, q) in cent.dequant.data.iter().zip(&asym.dequant.data) {
            assert_eq!(p.to_bits(), q.to_bits());
        }
        let meta = asym.grouped.as_ref().expect("scenario metadata");
        for j in 0..3 {
            assert_eq!(meta.groups[j].len(), 1);
            assert!(meta.outliers[j].is_empty());
            assert_eq!(meta.groups[j][0], (asym.scales[j], asym.offsets[j]));
        }
    }

    #[test]
    fn scenario_grouped_outlier_beats_dense_on_planted_outliers() {
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(7) };
        let m = 64;
        let n = 40;
        let x = Matrix::from_vec(m, n, g.vec_normal(m * n, 1.0));
        let mut w = Matrix::from_vec(n, 3, g.vec_normal(n * 3, 0.1));
        for j in 0..3 {
            // a dominating outlier per channel blows up the dense scale
            w[(5 + j, j)] = 12.0;
        }
        let a = alphabet(BitWidth::B2);
        let opts = BeaconOpts { loops: 3, ..Default::default() };
        let dense = beacon_layer(&x, &x, &w, &a, &opts);
        let sc = Scenario { group_size: 16, asymmetric: true, outlier_k: 1, ..Scenario::default() };
        let lq = beacon_layer_scenario(&x, &x, &w, &a, &opts, &sc);
        let err = |d: &Matrix| x.matmul(&w.sub(d)).frob_norm();
        assert!(
            err(&lq.dequant) < err(&dense.dequant),
            "grouped+outlier {} not better than dense {}",
            err(&lq.dequant),
            err(&dense.dequant)
        );
        let meta = lq.grouped.as_ref().expect("scenario metadata");
        for j in 0..3 {
            assert_eq!(meta.groups[j].len(), 3, "40 rows / g16 = 3 groups");
            assert_eq!(meta.outliers[j], vec![(5 + j, 12.0)]);
            assert_eq!(lq.dequant[(5 + j, j)], 12.0, "outlier kept exact");
            // codes (dummy included) live on the alphabet
            for v in &lq.codes[j] {
                assert!(a.iter().any(|p| (p - v).abs() < 1e-12), "{v} off-alphabet");
            }
        }
        // thread invariance of the scenario path
        let lq4 = beacon_layer_scenario(
            &x,
            &x,
            &w,
            &a,
            &BeaconOpts { threads: 4, ..opts },
            &sc,
        );
        for (p, q) in lq.dequant.data.iter().zip(&lq4.dequant.data) {
            assert_eq!(p.to_bits(), q.to_bits());
        }
    }

    #[test]
    fn layer_ec_handles_input_mismatch() {
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(6) };
        let (m, n, np) = (48, 8, 3);
        let x = Matrix::from_vec(m, n, g.vec_normal(m * n, 1.0));
        let mut xt = x.clone();
        for v in xt.data.iter_mut() {
            *v += 0.15 * g.normal();
        }
        let w = Matrix::from_vec(n, np, g.vec_normal(n * np, 0.3));
        let a = alphabet(BitWidth::B2);
        let opts = BeaconOpts::default();
        let ec = beacon_layer(&x, &xt, &w, &a, &opts);
        let no_ec = beacon_layer(&x, &x, &w, &a, &opts);
        // EC targets ||XW − X̃Q||; it must do at least as well there
        let err = |d: &Matrix| x.matmul(&w).sub(&xt.matmul(d)).frob_norm();
        assert!(err(&ec.dequant) <= err(&no_ec.dequant) + 1e-9);
    }

    // --- tie-breaking contract regression tests ---------------------------
    // These lock the scoring-rule contract shared with ref.py and the
    // Pallas kernel (module docs above): candidates scanned in ascending
    // order with strict `>` replacement, zero-denominator candidates score
    // −inf, and the degenerate u = 0 case picks the alphabet element
    // nearest the least-squares coefficient. The Quantizer-trait refactor
    // must never silently change any of these.

    #[test]
    fn tiebreak_ascending_scan_keeps_first() {
        // a = b = 0 ⇒ every candidate scores exactly 0; strict `>` keeps
        // the FIRST (most negative) alphabet element.
        let a = alphabet(BitWidth::B2);
        assert_eq!(argmax_scored(0.0, 0.0, 1.0, 0.0, 1.0, &a), -1.5);
    }

    #[test]
    fn zero_denominator_scores_neg_inf() {
        // den²(p) = cc + 2pd + p²e = (1 − p)² vanishes at p = 1: that
        // candidate must be skipped (−inf) even though its raw numerator
        // a + p·b = 5 is the largest on the grid.
        let tern = [-1.0, 0.0, 1.0];
        // scores: p=−1 → (0−5)/2 = −2.5, p=0 → 0, p=1 → −inf
        assert_eq!(argmax_scored(0.0, 5.0, 1.0, -1.0, 1.0, &tern), 0.0);
    }

    #[test]
    fn degenerate_u_picks_nearest_to_least_squares() {
        let a = alphabet(BitWidth::B2);
        // cc = 0 ⇒ least-squares coefficient b/e = 1.3 ⇒ nearest is 1.5
        assert_eq!(argmax_scored(0.0, 2.6, 0.0, 0.0, 2.0, &a), 1.5);
        // exact tie (ls = 0, dist 0.5 to ±0.5): ascending scan with
        // strict `<` keeps −0.5
        assert_eq!(argmax_scored(0.0, 0.0, 0.0, 0.0, 2.0, &a), -0.5);
    }

    #[test]
    fn degenerate_u_excludes_zero_energy_candidates() {
        // p = 0 has p²e = 0 ≤ EPS and is excluded even though it is the
        // nearest grid point to ls = 0.2; 1.0 (dist 0.8) wins over −1.0
        // (dist 1.2).
        let tern = [-1.0, 0.0, 1.0];
        assert_eq!(argmax_scored(0.0, 0.4, 0.0, 0.0, 2.0, &tern), 1.0);
    }

    #[test]
    fn zero_weight_channel_greedy_contract() {
        // End-to-end greedy pass over an all-zero channel: t = 0 goes
        // through the u = 0 branch (ls = 0 ⇒ first-nearest = −0.5); every
        // later coordinate ties at score 0 and keeps alph[0] = −1.5; the
        // integrated scale is exactly 0 (y = 0).
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(42) };
        let (x, _) = random_case(&mut g, 40, 8);
        let w = vec![0.0; 8];
        let (q, c) = channel_for(&x, &w, BitWidth::B2, 0);
        assert_eq!(q[0], -0.5);
        assert!(q[1..].iter().all(|&v| v == -1.5), "{q:?}");
        assert_eq!(c, 0.0);
    }

    #[test]
    fn triangular_prefix_matches_full() {
        // using lt_nnz = t+1 must give identical results to nnz = N
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(7) };
        let (x, w) = random_case(&mut g, 40, 8);
        let f = qr_factor(&x, &x);
        let a = alphabet(BitWidth::B2);
        let l_cols = f.l.columns();
        let lt_cols = f.r.columns();
        let tri: Vec<usize> = (0..8).map(|t| t + 1).collect();
        let full: Vec<usize> = vec![8; 8];
        let (q1, c1) = beacon_channel(&l_cols, &lt_cols, &tri, &w, &a, 4);
        let (q2, c2) = beacon_channel(&l_cols, &lt_cols, &full, &w, &a, 4);
        assert_eq!(q1, q2);
        assert!((c1 - c2).abs() < 1e-12);
    }
}
