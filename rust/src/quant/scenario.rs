//! The quantization *scenario*: the axes beyond `(method, bits)` that
//! shape a channel's grid — group size, symmetry, and outlier split.
//! See `docs/QUANT_SCENARIOS.md` for the full model; the short form:
//!
//! * **group_size** — `0` quantizes the whole channel against one
//!   scale/offset (the historical per-channel convention); `g > 0`
//!   slices the channel into `ceil(len/g)` groups, each with its own
//!   scale/offset (SpQR's `qq_groupsize` idea). The last group may be
//!   ragged.
//! * **asymmetric** — per-group zero points. The min-max family
//!   (RTN/GPTQ/COMQ) is *natively* asymmetric (`c·(k + z)` grids), so
//!   the flag is informational there; for Beacon it enables per-group
//!   centering (§3 generalized from channel means to group means, with
//!   the same corrected-mean restore `off_g = z_scale·mean_g`).
//! * **outlier_k** — keep the top-k magnitude weights of each channel
//!   exact in an f32 sidecar and quantize the rest (SpQR's core idea).
//!   Outlier slots still carry an on-grid dummy code so the bit stream
//!   stays dense and convention detection keeps working; decode paths
//!   substitute the sidecar value.
//!
//! Every helper here is deterministic (positional tie-breaks only), so
//! scenario quantization inherits the crate's bit-identical-at-any-
//! thread-count contract.

use crate::config::QuantConfig;
use crate::linalg::Matrix;

use super::engine::{GroupedMeta, LayerQuant};

/// The (group, symmetry, outlier) coordinates of a quantization run.
/// `Default` is the historical per-channel symmetric dense scenario.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Scenario {
    /// elements per scale/offset group; 0 = whole channel
    pub group_size: usize,
    /// per-group zero points (Beacon: per-group centering)
    pub asymmetric: bool,
    /// exact-f32 outliers kept per channel
    pub outlier_k: usize,
}

impl Scenario {
    /// The scenario a config asks for.
    pub fn from_config(qc: &QuantConfig) -> Scenario {
        Scenario {
            group_size: qc.group_size,
            asymmetric: qc.asymmetric,
            outlier_k: qc.outlier_k,
        }
    }

    /// The historical per-channel symmetric dense scenario.
    pub fn is_default(&self) -> bool {
        self.group_size == 0 && !self.asymmetric && self.outlier_k == 0
    }

    /// Whether a min-max-grid method (already per-channel asymmetric)
    /// needs the grouped/outlier path — the `asymmetric` flag alone
    /// changes nothing for that family.
    pub fn splits_channel(&self) -> bool {
        self.group_size > 0 || self.outlier_k > 0
    }

    /// Number of scale/offset groups for a channel of `len` elements.
    pub fn ngroups(&self, len: usize) -> usize {
        self.group_bounds(len).len()
    }

    /// Half-open `[lo, hi)` element ranges of each group, in order. The
    /// final group is ragged when `group_size` does not divide `len`.
    pub fn group_bounds(&self, len: usize) -> Vec<(usize, usize)> {
        if self.group_size == 0 || len == 0 {
            return vec![(0, len)];
        }
        let mut bounds = Vec::with_capacity((len + self.group_size - 1) / self.group_size);
        let mut lo = 0;
        while lo < len {
            let hi = (lo + self.group_size).min(len);
            bounds.push((lo, hi));
            lo = hi;
        }
        bounds
    }

    /// Label suffix in the `--override` spec grammar: `+g16+asym+k2`
    /// for the non-default axes, empty for the default scenario.
    pub fn label_suffix(&self) -> String {
        let mut s = String::new();
        if self.group_size > 0 {
            s.push_str(&format!("+g{}", self.group_size));
        }
        if self.asymmetric {
            s.push_str("+asym");
        }
        if self.outlier_k > 0 {
            s.push_str(&format!("+k{}", self.outlier_k));
        }
        s
    }
}

/// Indices of the top-`k` magnitude weights, ascending. Deterministic:
/// magnitude ties go to the lower index.
pub fn split_outliers(w: &[f64], k: usize) -> Vec<usize> {
    if k == 0 || w.is_empty() {
        return Vec::new();
    }
    let mut idx: Vec<usize> = (0..w.len()).collect();
    idx.sort_by(|&a, &b| {
        w[b].abs()
            .partial_cmp(&w[a].abs())
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut top: Vec<usize> = idx.into_iter().take(k.min(w.len())).collect();
    top.sort_unstable();
    top
}

/// One channel quantized under a scenario: full-length codes (outlier
/// slots hold an on-grid dummy), per-group `(scale, offset)` in the
/// factored-form convention (`dequant = scale·code + offset` for
/// non-outliers), the exact-value outlier sidecar (ascending rows), and
/// the authoritative dequantized values (outlier slots hold the exact
/// weight).
#[derive(Debug, Clone)]
pub struct ChannelQuant {
    pub codes: Vec<f64>,
    pub groups: Vec<(f64, f64)>,
    pub outliers: Vec<(usize, f64)>,
    pub dequant: Vec<f64>,
}

/// Gather per-channel scenario results into the engine's [`LayerQuant`]
/// form. `scales`/`offsets` mirror each channel's first group so legacy
/// per-channel consumers keep working; the full per-group table and the
/// sidecar ride in [`GroupedMeta`].
pub fn assemble_layer(n: usize, results: Vec<ChannelQuant>, sc: &Scenario) -> LayerQuant {
    let np = results.len();
    let mut dequant = Matrix::zeros(n, np);
    let mut codes = Vec::with_capacity(np);
    let mut scales = Vec::with_capacity(np);
    let mut offsets = Vec::with_capacity(np);
    let mut groups = Vec::with_capacity(np);
    let mut outliers = Vec::with_capacity(np);
    for (j, ch) in results.into_iter().enumerate() {
        dequant.set_col(j, &ch.dequant);
        let (s0, o0) = *ch.groups.first().expect("at least one group per channel");
        scales.push(s0);
        offsets.push(o0);
        codes.push(ch.codes);
        groups.push(ch.groups);
        outliers.push(ch.outliers);
    }
    LayerQuant {
        codes,
        scales,
        offsets,
        dequant,
        grouped: Some(GroupedMeta { group_size: sc.group_size, groups, outliers }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_scenario_is_default() {
        let sc = Scenario::default();
        assert!(sc.is_default());
        assert!(!sc.splits_channel());
        assert_eq!(sc.label_suffix(), "");
        assert_eq!(sc.group_bounds(10), vec![(0, 10)]);
        assert_eq!(sc.ngroups(10), 1);
    }

    #[test]
    fn group_bounds_cover_ragged_tails() {
        let sc = Scenario { group_size: 16, ..Scenario::default() };
        assert_eq!(sc.group_bounds(40), vec![(0, 16), (16, 32), (32, 40)]);
        assert_eq!(sc.ngroups(40), 3);
        assert_eq!(sc.group_bounds(16), vec![(0, 16)]);
        assert_eq!(sc.group_bounds(0), vec![(0, 0)]);
        // bounds partition [0, len)
        let b = sc.group_bounds(45);
        assert_eq!(b.first().unwrap().0, 0);
        assert_eq!(b.last().unwrap().1, 45);
        for w in b.windows(2) {
            assert_eq!(w[0].1, w[1].0);
        }
    }

    #[test]
    fn label_suffix_matches_spec_grammar() {
        let sc = Scenario { group_size: 16, asymmetric: true, outlier_k: 2 };
        assert_eq!(sc.label_suffix(), "+g16+asym+k2");
        assert!(!sc.is_default());
        assert!(sc.splits_channel());
        let sc = Scenario { asymmetric: true, ..Scenario::default() };
        assert_eq!(sc.label_suffix(), "+asym");
        assert!(!sc.is_default());
        assert!(!sc.splits_channel());
    }

    #[test]
    fn split_outliers_deterministic_top_k() {
        let w = [0.1, -3.0, 0.2, 3.0, -0.05];
        // |w| ties between indices 1 and 3 → lower index first, but both
        // land in the top-2 anyway; result is ascending
        assert_eq!(split_outliers(&w, 2), vec![1, 3]);
        assert_eq!(split_outliers(&w, 1), vec![1]);
        assert_eq!(split_outliers(&w, 0), Vec::<usize>::new());
        // k larger than the channel keeps every index
        assert_eq!(split_outliers(&w, 99), vec![0, 1, 2, 3, 4]);
        // exact magnitude tie: lower index wins the last slot
        let t = [1.0, -2.0, 2.0];
        assert_eq!(split_outliers(&t, 1), vec![1]);
    }
}
