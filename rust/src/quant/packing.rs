//! Deployment bit-packing: Beacon's codes are indices into the (known,
//! unscaled) alphabet, so a quantized channel ships as
//! `ceil(bits)`-bit indices + one f32 scale (+ one f32 offset when
//! centered) — the storage model the paper's memory numbers assume.

use super::alphabet::{alphabet, BitWidth};

/// Which value the packed indices decode through. The repo carries two
/// code conventions: Beacon emits alphabet *values* (±0.5, ±1.5, …)
/// whose index decodes through the alphabet, while the min-max methods
/// (RTN/GPTQ/COMQ) emit integer level indices `k ∈ [0, levels)` whose
/// dequant is `scale·k + offset` directly. A packed channel records
/// which convention produced it so unpacking is never ambiguous —
/// previously `unpack_channel` assumed the alphabet convention and
/// silently decoded integer-level channels to the wrong values.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CodeConvention {
    /// index decodes to `alphabet[idx]`
    Alphabet,
    /// index decodes to `idx` itself (min-max level index)
    Levels,
}

#[derive(Debug, Clone)]
pub struct PackedChannel {
    pub bits: u32,
    pub len: usize,
    /// group 0's scale (the whole channel's under the dense scenario)
    pub scale: f32,
    /// group 0's offset (the whole channel's under the dense scenario)
    pub offset: f32,
    pub convention: CodeConvention,
    /// rows per group; 0 = one (scale, offset) for the whole channel
    pub group_size: u32,
    /// per-group (scale, offset) when grouped — empty for a dense
    /// channel, where `scale`/`offset` above are authoritative; when
    /// non-empty, `scale`/`offset` mirror `groups[0]`
    pub groups: Vec<(f32, f32)>,
    /// outlier sidecar: (row, exact value), rows strictly ascending.
    /// The bit stream still carries an on-grid dummy code at these
    /// rows, so decode substitutes *after* the LUT read.
    pub outliers: Vec<(u32, f32)>,
    /// little-endian bit stream, `bits` bits per element
    pub words: Vec<u64>,
}

impl PackedChannel {
    /// Heap + inline footprint of this packed channel, for the
    /// resident-bytes registry.
    pub fn resident_bytes(&self) -> usize {
        self.words.len() * 8
            + self.groups.len() * 8
            + self.outliers.len() * 8
            + std::mem::size_of::<PackedChannel>()
    }

    /// Dense scenario: one (scale, offset) for the channel, no sidecar.
    /// Dense channels serialize as BPK1; anything else needs BPK2.
    pub fn is_dense(&self) -> bool {
        self.group_size == 0 && self.groups.is_empty() && self.outliers.is_empty()
    }

    /// The per-group (scale, offset) list with the dense case folded in
    /// as a single group — every decode path iterates this uniformly.
    pub fn effective_groups(&self) -> Vec<(f32, f32)> {
        if self.groups.is_empty() {
            vec![(self.scale, self.offset)]
        } else {
            self.groups.clone()
        }
    }
}

/// Pack pre-resolved indices into the bit stream under the given
/// decode convention.
pub fn pack_indices(
    idxs: &[usize],
    scale: f64,
    offset: f64,
    width: BitWidth,
    convention: CodeConvention,
) -> PackedChannel {
    let bits = width.storage_bits();
    let mut words = vec![0u64; (idxs.len() * bits as usize + 63) / 64];
    for (i, &k) in idxs.iter().enumerate() {
        let idx = k as u64;
        let bitpos = i * bits as usize;
        let (word, off) = (bitpos / 64, bitpos % 64);
        words[word] |= idx << off;
        if off + bits as usize > 64 {
            words[word + 1] |= idx >> (64 - off);
        }
    }
    PackedChannel {
        bits,
        len: idxs.len(),
        scale: scale as f32,
        offset: offset as f32,
        convention,
        group_size: 0,
        groups: Vec::new(),
        outliers: Vec::new(),
        words,
    }
}

/// Map code values (alphabet elements) to indices and pack. Panics on
/// off-alphabet codes; see [`try_pack_channel`] for the tolerant form.
pub fn pack_channel(
    codes: &[f64],
    scale: f64,
    offset: f64,
    width: BitWidth,
) -> PackedChannel {
    let alph = alphabet(width);
    let idxs: Vec<usize> = codes
        .iter()
        .map(|v| {
            alph.iter()
                .position(|a| (a - v).abs() < 1e-9)
                .unwrap_or_else(|| panic!("code {v} not on {width:?} alphabet"))
        })
        .collect();
    pack_indices(&idxs, scale, offset, width, CodeConvention::Alphabet)
}

/// Resolve a whole channel's codes to indices plus the convention that
/// matched. The decision is per *channel*, not per element: a channel of
/// integer level indices like `[0, 1, 2]` contains values that also sit
/// on some alphabets (the ternary grid holds 0 and 1), so element-wise
/// detection could mix conventions inside one channel and decode
/// garbage. The alphabet reading wins when every code satisfies both
/// (only possible on the integer-valued 1.58-bit grid, where either
/// reading is self-consistent).
fn detect_convention(
    codes: &[f64],
    alph: &[f64],
    levels: usize,
) -> Option<(CodeConvention, Vec<usize>)> {
    let alphabet_idxs: Option<Vec<usize>> = codes
        .iter()
        .map(|v| alph.iter().position(|a| (a - v).abs() < 1e-9))
        .collect();
    if let Some(idxs) = alphabet_idxs {
        return Some((CodeConvention::Alphabet, idxs));
    }
    let level_idxs: Option<Vec<usize>> = codes
        .iter()
        .map(|v| {
            let k = v.round();
            if (k - v).abs() < 1e-9 && k >= 0.0 && k < levels as f64 {
                Some(k as usize)
            } else {
                None
            }
        })
        .collect();
    level_idxs.map(|idxs| (CodeConvention::Levels, idxs))
}

/// Pack a channel whose codes follow either convention (alphabet values
/// or integer level indices); `None` when any code is off-grid — the
/// footprint accounting degrades gracefully instead of panicking. The
/// matched convention is recorded on the channel so
/// [`unpack_channel`] decodes through the right mapping.
pub fn try_pack_channel(
    codes: &[f64],
    scale: f64,
    offset: f64,
    width: BitWidth,
) -> Option<PackedChannel> {
    let alph = alphabet(width);
    let levels = alph.len();
    let (convention, idxs) = detect_convention(codes, &alph, levels)?;
    Some(pack_indices(&idxs, scale, offset, width, convention))
}

/// Pack a channel under a grouped / outlier-split scenario: the bit
/// stream carries every row's code (outlier rows hold the quantizer's
/// on-grid dummy, so convention detection sees a fully on-grid
/// channel), `groups` carries each group's (scale, offset), and
/// `outliers` the exact sidecar values at strictly ascending rows.
/// `None` when any code is off-grid, like [`try_pack_channel`].
pub fn pack_channel_grouped(
    codes: &[f64],
    groups: &[(f64, f64)],
    group_size: usize,
    outliers: &[(usize, f64)],
    width: BitWidth,
) -> Option<PackedChannel> {
    let alph = alphabet(width);
    let (convention, idxs) = detect_convention(codes, &alph, alph.len())?;
    let (s0, o0) = groups.first().copied().unwrap_or((1.0, 0.0));
    let mut p = pack_indices(&idxs, s0, o0, width, convention);
    p.group_size = group_size as u32;
    p.groups = groups.iter().map(|&(c, o)| (c as f32, o as f32)).collect();
    p.outliers = outliers.iter().map(|&(i, v)| (i as u32, v as f32)).collect();
    Some(p)
}

/// Packed storage for a whole layer's codes without materializing the
/// bit streams: `(payload_bytes, meta_bytes)` where payload is
/// Σ ceil(len·bits/8) and meta is 8 bytes (scale + offset f32) per
/// channel. `None` when any channel has off-grid codes.
pub fn layer_packed_bytes(
    codes: &[Vec<f64>],
    width: BitWidth,
) -> Option<(u64, u64)> {
    let alph = alphabet(width);
    let levels = alph.len();
    let bits = width.storage_bits() as u64;
    let mut payload = 0u64;
    for ch in codes {
        detect_convention(ch, &alph, levels)?;
        payload += (ch.len() as u64 * bits + 7) / 8;
    }
    Some((payload, codes.len() as u64 * 8))
}

/// Unpack the raw alphabet indices (the lossless payload: packing is
/// exact on indices, while dequantized values go through f32).
pub fn unpack_indices(p: &PackedChannel) -> Vec<usize> {
    let mask = if p.bits == 64 { u64::MAX } else { (1u64 << p.bits) - 1 };
    (0..p.len)
        .map(|i| {
            let bitpos = i * p.bits as usize;
            let (word, off) = (bitpos / 64, bitpos % 64);
            let mut idx = p.words[word] >> off;
            if off + p.bits as usize > 64 {
                idx |= p.words[word + 1] << (64 - off);
            }
            (idx & mask) as usize
        })
        .collect()
}

/// The per-index dequantized values for this channel, covering the full
/// `2^bits` index space of the stored width: `lut[k] = scale·v(k) +
/// offset` in f32, where `v(k)` is `alphabet[k]` or `k` per the
/// channel's [`CodeConvention`]. Indices past the grid's level count
/// (possible only in a corrupt bit stream) repeat the last grid value
/// for the alphabet convention, so LUT-driven decode paths never index
/// out of bounds. This is the exact table the fused
/// [`crate::linalg::packed_gemm`] kernel expands codes through —
/// `unpack_channel` is defined as a lookup into it, which is what makes
/// the fused path bit-identical to unpack-then-compute.
pub fn dequant_lut(p: &PackedChannel, width: BitWidth) -> Vec<f32> {
    let alph = alphabet(width);
    let space = 1usize << p.bits;
    (0..space)
        .map(|k| {
            let base = match p.convention {
                CodeConvention::Alphabet => {
                    alph[k.min(alph.len() - 1)] as f32
                }
                CodeConvention::Levels => k as f32,
            };
            p.scale * base + p.offset
        })
        .collect()
}

/// The concatenated per-group dequant tables: one `2^bits` stride per
/// entry of [`PackedChannel::effective_groups`], laid out group-major —
/// `luts[g·2^bits + k] = scale_g·v(k) + offset_g`. For a dense channel
/// this is exactly [`dequant_lut`]. The fused
/// [`crate::linalg::packed_gemm`] kernel swaps its LUT base at group
/// boundaries by walking this table.
pub fn dequant_luts(p: &PackedChannel, width: BitWidth) -> Vec<f32> {
    let alph = alphabet(width);
    let space = 1usize << p.bits;
    let groups = p.effective_groups();
    let mut lut = Vec::with_capacity(space * groups.len());
    for (scale, offset) in groups {
        for k in 0..space {
            let base = match p.convention {
                CodeConvention::Alphabet => alph[k.min(alph.len() - 1)] as f32,
                CodeConvention::Levels => k as f32,
            };
            lut.push(scale * base + offset);
        }
    }
    lut
}

/// Unpack to dequantized f32 values: each row decodes through its own
/// group's (scale, offset) table, then outlier rows substitute their
/// exact sidecar value. Dense channels take the single-group case of
/// the same path.
pub fn unpack_channel(p: &PackedChannel, width: BitWidth) -> Vec<f32> {
    let luts = dequant_luts(p, width);
    let step = 1usize << p.bits;
    let gs = p.group_size as usize;
    let mut oi = 0usize;
    unpack_indices(p)
        .into_iter()
        .enumerate()
        .map(|(i, idx)| {
            let g = if gs == 0 { 0 } else { i / gs };
            if oi < p.outliers.len() && p.outliers[oi].0 as usize == i {
                let v = p.outliers[oi].1;
                oi += 1;
                v
            } else {
                luts[g * step + idx]
            }
        })
        .collect()
}

/// Effective storage bytes for the packed channel: codes plus 8 bytes
/// of (scale, offset) per effective group plus 8 bytes per outlier
/// sidecar entry (row u32 + value f32).
pub fn packed_bytes(p: &PackedChannel) -> usize {
    let ngroups = if p.groups.is_empty() { 1 } else { p.groups.len() };
    (p.len * p.bits as usize + 7) / 8 + 8 * ngroups + 8 * p.outliers.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn roundtrip_all_widths() {
        prop_check(20, |g| {
            for width in BitWidth::ALL {
                let alph = alphabet(width);
                let n = g.usize_in(1, 70);
                let codes: Vec<f64> =
                    (0..n).map(|_| *g.pick(&alph)).collect();
                let scale = g.f64_in(0.01, 2.0);
                let off = g.f64_in(-0.2, 0.2);
                let p = pack_channel(&codes, scale, off, width);
                let back = unpack_channel(&p, width);
                for (c, b) in codes.iter().zip(&back) {
                    let expect = (scale as f32) * (*c as f32) + off as f32;
                    if (expect - b).abs() > 1e-6 {
                        return Err(format!("{width:?}: {expect} vs {b}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn compression_ratio() {
        let width = BitWidth::B2;
        let alph = alphabet(width);
        let codes: Vec<f64> = (0..1024).map(|i| alph[i % 4]).collect();
        let p = pack_channel(&codes, 0.1, 0.0, width);
        let bytes = packed_bytes(&p);
        // 1024 weights at f32 = 4096 bytes; 2-bit packed ≈ 256 + 8
        assert!(bytes <= 264, "{bytes}");
        assert!(4096 / bytes >= 15);
    }

    #[test]
    fn word_boundary_crossing() {
        // 3-bit codes cross u64 boundaries at element 21
        let width = BitWidth::B3;
        let alph = alphabet(width);
        let codes: Vec<f64> = (0..64).map(|i| alph[i % 8]).collect();
        let p = pack_channel(&codes, 1.0, 0.0, width);
        let back = unpack_channel(&p, width);
        for (i, b) in back.iter().enumerate() {
            assert!((*b - alph[i % 8] as f32).abs() < 1e-6, "elem {i}");
        }
    }

    #[test]
    #[should_panic(expected = "not on")]
    fn rejects_off_grid_codes() {
        pack_channel(&[0.25], 1.0, 0.0, BitWidth::B2);
    }

    #[test]
    fn indices_roundtrip_bit_identical() {
        // pack → unpack_indices must be lossless at every storage width,
        // including ragged tails that leave a partial final word.
        for (width, n) in [
            (BitWidth::B2, 70usize), // 140 bits: 12 bits spill past word 2
            (BitWidth::B3, 70),      // 210 bits: tail + boundary crossings
            (BitWidth::B4, 70),      // 280 bits
            (BitWidth::B2, 1),       // single element
            (BitWidth::B3, 64),      // exact multiple of elements
        ] {
            let alph = alphabet(width);
            let lv = alph.len();
            let want: Vec<usize> = (0..n).map(|i| (i * 7 + 3) % lv).collect();
            let codes: Vec<f64> = want.iter().map(|&k| alph[k]).collect();
            let p = pack_channel(&codes, 0.37, -0.05, width);
            assert_eq!(unpack_indices(&p), want, "{width:?} n={n}");
        }
    }

    #[test]
    fn ragged_tail_words_are_exact() {
        // 70 × 3-bit = 210 bits → 4 words, last holds 18 live bits; the
        // elements straddling words 1/2 and 2/3 (indices 21 and 42) and
        // the final element must all survive.
        let width = BitWidth::B3;
        let alph = alphabet(width);
        let want: Vec<usize> = (0..70).map(|i| i % 8).collect();
        let codes: Vec<f64> = want.iter().map(|&k| alph[k]).collect();
        let p = pack_channel(&codes, 1.0, 0.0, width);
        assert_eq!(p.words.len(), 4);
        let got = unpack_indices(&p);
        assert_eq!(got[21], want[21]);
        assert_eq!(got[42], want[42]);
        assert_eq!(got[69], want[69]);
        assert_eq!(got, want);
    }

    #[test]
    fn try_pack_accepts_alphabet_codes() {
        // Beacon convention: codes are alphabet values
        let width = BitWidth::B2;
        let alph = alphabet(width);
        let want: Vec<usize> = (0..70).map(|i| i % 4).collect();
        let codes: Vec<f64> = want.iter().map(|&k| alph[k]).collect();
        let p = try_pack_channel(&codes, 0.2, 0.0, width).unwrap();
        assert_eq!(unpack_indices(&p), want);
        // identical to the panicking path
        let q = pack_channel(&codes, 0.2, 0.0, width);
        assert_eq!(p.words, q.words);
    }

    #[test]
    fn try_pack_accepts_integer_index_codes() {
        // min-max convention (RTN/GPTQ/COMQ): codes are level indices
        let width = BitWidth::B3;
        let want: Vec<usize> = (0..70).map(|i| (i * 5 + 1) % 8).collect();
        let codes: Vec<f64> = want.iter().map(|&k| k as f64).collect();
        let p = try_pack_channel(&codes, 1.0, 0.0, width).unwrap();
        assert_eq!(unpack_indices(&p), want);
    }

    #[test]
    fn both_conventions_roundtrip_bit_identical_f32() {
        // The convention-asymmetry regression test: for BOTH code
        // conventions try_pack_channel accepts, pack → unpack must
        // reproduce the dequantized f32 values bit-for-bit — including
        // ragged tails that straddle and partially fill u64 words at
        // every storage width.
        for (width, n) in [
            (BitWidth::B2, 70usize), // 140 bits: ragged tail in word 3
            (BitWidth::B3, 70),      // 210 bits: straddles + ragged tail
            (BitWidth::B4, 70),      // 280 bits: ragged tail
            (BitWidth::B2, 1),
            (BitWidth::B3, 64), // exact element multiple, ragged bits
            (BitWidth::B4, 32), // exact word multiple
        ] {
            let alph = alphabet(width);
            let lv = alph.len();
            let (scale, offset) = (0.37f64, -0.05f64);
            let want_idx: Vec<usize> =
                (0..n).map(|i| (i * 7 + 3) % lv).collect();

            // alphabet-value convention (Beacon)
            let codes_a: Vec<f64> =
                want_idx.iter().map(|&k| alph[k]).collect();
            let p = try_pack_channel(&codes_a, scale, offset, width).unwrap();
            assert_eq!(p.convention, CodeConvention::Alphabet, "{width:?}");
            let back = unpack_channel(&p, width);
            for (i, (&k, b)) in want_idx.iter().zip(&back).enumerate() {
                let expect =
                    scale as f32 * alph[k] as f32 + offset as f32;
                assert_eq!(
                    expect.to_bits(),
                    b.to_bits(),
                    "{width:?} alphabet n={n} elem {i}"
                );
            }

            // integer-level convention (RTN/GPTQ/COMQ)
            let codes_l: Vec<f64> =
                want_idx.iter().map(|&k| k as f64).collect();
            let p = try_pack_channel(&codes_l, scale, offset, width).unwrap();
            assert_eq!(unpack_indices(&p), want_idx, "{width:?} levels n={n}");
            let back = unpack_channel(&p, width);
            for (i, (&k, b)) in want_idx.iter().zip(&back).enumerate() {
                let expect = scale as f32 * k as f32 + offset as f32;
                assert_eq!(
                    expect.to_bits(),
                    b.to_bits(),
                    "{width:?} levels n={n} elem {i}"
                );
            }
        }
    }

    #[test]
    fn level_channels_decode_as_levels_not_alphabet() {
        // the bug the convention field fixes: a min-max channel packed
        // as level indices used to decode through the alphabet
        let width = BitWidth::B3;
        let codes: Vec<f64> = (0..8).map(|k| k as f64).collect();
        let p = try_pack_channel(&codes, 0.5, 0.25, width).unwrap();
        assert_eq!(p.convention, CodeConvention::Levels);
        let back = unpack_channel(&p, width);
        for (k, b) in back.iter().enumerate() {
            let expect = 0.5f32 * k as f32 + 0.25f32;
            assert_eq!(expect.to_bits(), b.to_bits(), "level {k}");
        }
    }

    #[test]
    fn convention_is_per_channel_not_per_element() {
        // [0, 1, 2] on the ternary grid: 0 and 1 sit on the alphabet
        // but 2 does not, so the whole channel must resolve as Levels
        let width = BitWidth::B158;
        let p = try_pack_channel(&[0.0, 1.0, 2.0], 1.0, 0.0, width).unwrap();
        assert_eq!(p.convention, CodeConvention::Levels);
        assert_eq!(unpack_indices(&p), vec![0, 1, 2]);
        // all-on-alphabet stays Alphabet (alphabet wins the ambiguity)
        let p = try_pack_channel(&[0.0, 1.0, -1.0], 1.0, 0.0, width).unwrap();
        assert_eq!(p.convention, CodeConvention::Alphabet);
    }

    #[test]
    fn dequant_lut_covers_full_index_space() {
        let width = BitWidth::B258; // 6 levels in a 3-bit index space
        let alph = alphabet(width);
        let codes: Vec<f64> = (0..10).map(|i| alph[i % 6]).collect();
        let p = try_pack_channel(&codes, 0.2, 0.1, width).unwrap();
        let lut = dequant_lut(&p, width);
        assert_eq!(lut.len(), 8);
        for k in 0..6 {
            let expect = 0.2f32 * alph[k] as f32 + 0.1f32;
            assert_eq!(expect.to_bits(), lut[k].to_bits());
        }
        // out-of-grid indices clamp to the last grid value
        assert_eq!(lut[6].to_bits(), lut[5].to_bits());
        assert_eq!(lut[7].to_bits(), lut[5].to_bits());
    }

    #[test]
    fn try_pack_rejects_off_grid() {
        assert!(try_pack_channel(&[0.25], 1.0, 0.0, BitWidth::B2).is_none());
        assert!(try_pack_channel(&[-1.0], 1.0, 0.0, BitWidth::B4).is_none());
        assert!(try_pack_channel(&[16.0], 1.0, 0.0, BitWidth::B4).is_none());
    }

    #[test]
    fn layer_packed_bytes_matches_per_channel_packing() {
        let width = BitWidth::B2;
        let alph = alphabet(width);
        let codes: Vec<Vec<f64>> = (0..4)
            .map(|c| (0..70).map(|i| alph[(i + c) % 4]).collect())
            .collect();
        let (payload, meta) = layer_packed_bytes(&codes, width).unwrap();
        // 70 × 2 bits = 140 bits → 18 bytes per channel
        assert_eq!(payload, 4 * 18);
        assert_eq!(meta, 4 * 8);
        assert!(layer_packed_bytes(&[vec![0.25]], width).is_none());
    }

    #[test]
    fn grouped_pack_roundtrip_with_outliers() {
        // 40 × 3-bit level codes, g16 (ragged 8-row tail group), one
        // exact outlier at row 5 riding an on-grid dummy code
        let width = BitWidth::B3;
        let want: Vec<usize> = (0..40).map(|i| (i * 5 + 1) % 8).collect();
        let codes: Vec<f64> = want.iter().map(|&k| k as f64).collect();
        let groups = [(0.5, 0.125), (0.25, -0.25), (1.0, 0.0)];
        let outliers = [(5usize, 9.0f64)];
        let p = pack_channel_grouped(&codes, &groups, 16, &outliers, width).unwrap();
        assert!(!p.is_dense());
        assert_eq!(p.group_size, 16);
        assert_eq!((p.scale, p.offset), (0.5, 0.125), "mirror group 0");
        assert_eq!(p.convention, CodeConvention::Levels);
        assert_eq!(unpack_indices(&p), want, "bit stream covers every row");
        assert_eq!(dequant_luts(&p, width).len(), 3 * 8);
        let back = unpack_channel(&p, width);
        for (i, b) in back.iter().enumerate() {
            if i == 5 {
                assert_eq!(b.to_bits(), 9.0f32.to_bits(), "outlier exact");
                continue;
            }
            let (c, o) = groups[i / 16];
            let expect = c as f32 * want[i] as f32 + o as f32;
            assert_eq!(expect.to_bits(), b.to_bits(), "row {i}");
        }
        // footprint: payload + 8 bytes per group + 8 per outlier
        assert_eq!(packed_bytes(&p), (40 * 3 + 7) / 8 + 8 * 3 + 8);
        assert!(pack_channel_grouped(&[0.33], &groups, 16, &[], width).is_none());
    }

    #[test]
    fn dense_packing_is_unchanged_by_scenario_fields() {
        let width = BitWidth::B2;
        let alph = alphabet(width);
        let codes: Vec<f64> = (0..70).map(|i| alph[i % 4]).collect();
        let p = try_pack_channel(&codes, 0.2, 0.0, width).unwrap();
        assert!(p.is_dense());
        assert_eq!(p.effective_groups(), vec![(p.scale, p.offset)]);
        assert_eq!(packed_bytes(&p), (70 * 2 + 7) / 8 + 8);
        // dequant_luts degenerates to dequant_lut bit-for-bit
        let a = dequant_lut(&p, width);
        let b = dequant_luts(&p, width);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn resident_bytes_covers_words() {
        let width = BitWidth::B2;
        let alph = alphabet(width);
        let codes: Vec<f64> = (0..256).map(|i| alph[i % 4]).collect();
        let p = pack_channel(&codes, 1.0, 0.0, width);
        // 512 bits = 8 words
        assert!(p.resident_bytes() >= 64);
    }

    #[test]
    fn packed_bytes_vs_f32() {
        // the storage model the paper's memory numbers assume: n f32
        // weights (4n bytes) → ceil(n·bits/8) + 8 bytes of metadata
        for (width, n, payload) in [
            (BitWidth::B2, 1000usize, 250usize),
            (BitWidth::B3, 1000, 375),
            (BitWidth::B4, 1000, 500),
            (BitWidth::B3, 70, 27), // ragged: ceil(210/8)
        ] {
            let alph = alphabet(width);
            let codes: Vec<f64> = (0..n).map(|i| alph[i % alph.len()]).collect();
            let p = pack_channel(&codes, 1.0, 0.0, width);
            assert_eq!(packed_bytes(&p), payload + 8, "{width:?}");
            assert!(packed_bytes(&p) < n * 4, "{width:?} must beat f32");
        }
    }
}
