//! Deployment bit-packing: Beacon's codes are indices into the (known,
//! unscaled) alphabet, so a quantized channel ships as
//! `ceil(bits)`-bit indices + one f32 scale (+ one f32 offset when
//! centered) — the storage model the paper's memory numbers assume.

use super::alphabet::{alphabet, BitWidth};

#[derive(Debug, Clone)]
pub struct PackedChannel {
    pub bits: u32,
    pub len: usize,
    pub scale: f32,
    pub offset: f32,
    /// little-endian bit stream, `bits` bits per element
    pub words: Vec<u64>,
}

/// Map code values (alphabet elements) to indices and pack.
pub fn pack_channel(
    codes: &[f64],
    scale: f64,
    offset: f64,
    width: BitWidth,
) -> PackedChannel {
    let alph = alphabet(width);
    let bits = width.storage_bits();
    let mut words = vec![0u64; (codes.len() * bits as usize + 63) / 64];
    for (i, v) in codes.iter().enumerate() {
        let idx = alph
            .iter()
            .position(|a| (a - v).abs() < 1e-9)
            .unwrap_or_else(|| panic!("code {v} not on {width:?} alphabet"))
            as u64;
        let bitpos = i * bits as usize;
        let (word, off) = (bitpos / 64, bitpos % 64);
        words[word] |= idx << off;
        if off + bits as usize > 64 {
            words[word + 1] |= idx >> (64 - off);
        }
    }
    PackedChannel {
        bits,
        len: codes.len(),
        scale: scale as f32,
        offset: offset as f32,
        words,
    }
}

/// Unpack the raw alphabet indices (the lossless payload: packing is
/// exact on indices, while dequantized values go through f32).
pub fn unpack_indices(p: &PackedChannel) -> Vec<usize> {
    let mask = if p.bits == 64 { u64::MAX } else { (1u64 << p.bits) - 1 };
    (0..p.len)
        .map(|i| {
            let bitpos = i * p.bits as usize;
            let (word, off) = (bitpos / 64, bitpos % 64);
            let mut idx = p.words[word] >> off;
            if off + p.bits as usize > 64 {
                idx |= p.words[word + 1] << (64 - off);
            }
            (idx & mask) as usize
        })
        .collect()
}

/// Unpack to dequantized f32 values (c·q + offset).
pub fn unpack_channel(p: &PackedChannel, width: BitWidth) -> Vec<f32> {
    let alph = alphabet(width);
    unpack_indices(p)
        .into_iter()
        .map(|idx| p.scale * alph[idx] as f32 + p.offset)
        .collect()
}

/// Effective storage bytes for the packed channel (codes + metadata).
pub fn packed_bytes(p: &PackedChannel) -> usize {
    (p.len * p.bits as usize + 7) / 8 + 8 // + scale & offset f32s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn roundtrip_all_widths() {
        prop_check(20, |g| {
            for width in BitWidth::ALL {
                let alph = alphabet(width);
                let n = g.usize_in(1, 70);
                let codes: Vec<f64> =
                    (0..n).map(|_| *g.pick(&alph)).collect();
                let scale = g.f64_in(0.01, 2.0);
                let off = g.f64_in(-0.2, 0.2);
                let p = pack_channel(&codes, scale, off, width);
                let back = unpack_channel(&p, width);
                for (c, b) in codes.iter().zip(&back) {
                    let expect = (scale as f32) * (*c as f32) + off as f32;
                    if (expect - b).abs() > 1e-6 {
                        return Err(format!("{width:?}: {expect} vs {b}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn compression_ratio() {
        let width = BitWidth::B2;
        let alph = alphabet(width);
        let codes: Vec<f64> = (0..1024).map(|i| alph[i % 4]).collect();
        let p = pack_channel(&codes, 0.1, 0.0, width);
        let bytes = packed_bytes(&p);
        // 1024 weights at f32 = 4096 bytes; 2-bit packed ≈ 256 + 8
        assert!(bytes <= 264, "{bytes}");
        assert!(4096 / bytes >= 15);
    }

    #[test]
    fn word_boundary_crossing() {
        // 3-bit codes cross u64 boundaries at element 21
        let width = BitWidth::B3;
        let alph = alphabet(width);
        let codes: Vec<f64> = (0..64).map(|i| alph[i % 8]).collect();
        let p = pack_channel(&codes, 1.0, 0.0, width);
        let back = unpack_channel(&p, width);
        for (i, b) in back.iter().enumerate() {
            assert!((*b - alph[i % 8] as f32).abs() < 1e-6, "elem {i}");
        }
    }

    #[test]
    #[should_panic(expected = "not on")]
    fn rejects_off_grid_codes() {
        pack_channel(&[0.25], 1.0, 0.0, BitWidth::B2);
    }

    #[test]
    fn indices_roundtrip_bit_identical() {
        // pack → unpack_indices must be lossless at every storage width,
        // including ragged tails that leave a partial final word.
        for (width, n) in [
            (BitWidth::B2, 70usize), // 140 bits: 12 bits spill past word 2
            (BitWidth::B3, 70),      // 210 bits: tail + boundary crossings
            (BitWidth::B4, 70),      // 280 bits
            (BitWidth::B2, 1),       // single element
            (BitWidth::B3, 64),      // exact multiple of elements
        ] {
            let alph = alphabet(width);
            let lv = alph.len();
            let want: Vec<usize> = (0..n).map(|i| (i * 7 + 3) % lv).collect();
            let codes: Vec<f64> = want.iter().map(|&k| alph[k]).collect();
            let p = pack_channel(&codes, 0.37, -0.05, width);
            assert_eq!(unpack_indices(&p), want, "{width:?} n={n}");
        }
    }

    #[test]
    fn ragged_tail_words_are_exact() {
        // 70 × 3-bit = 210 bits → 4 words, last holds 18 live bits; the
        // elements straddling words 1/2 and 2/3 (indices 21 and 42) and
        // the final element must all survive.
        let width = BitWidth::B3;
        let alph = alphabet(width);
        let want: Vec<usize> = (0..70).map(|i| i % 8).collect();
        let codes: Vec<f64> = want.iter().map(|&k| alph[k]).collect();
        let p = pack_channel(&codes, 1.0, 0.0, width);
        assert_eq!(p.words.len(), 4);
        let got = unpack_indices(&p);
        assert_eq!(got[21], want[21]);
        assert_eq!(got[42], want[42]);
        assert_eq!(got[69], want[69]);
        assert_eq!(got, want);
    }

    #[test]
    fn packed_bytes_vs_f32() {
        // the storage model the paper's memory numbers assume: n f32
        // weights (4n bytes) → ceil(n·bits/8) + 8 bytes of metadata
        for (width, n, payload) in [
            (BitWidth::B2, 1000usize, 250usize),
            (BitWidth::B3, 1000, 375),
            (BitWidth::B4, 1000, 500),
            (BitWidth::B3, 70, 27), // ragged: ceil(210/8)
        ] {
            let alph = alphabet(width);
            let codes: Vec<f64> = (0..n).map(|i| alph[i % alph.len()]).collect();
            let p = pack_channel(&codes, 1.0, 0.0, width);
            assert_eq!(packed_bytes(&p), payload + 8, "{width:?}");
            assert!(packed_bytes(&p) < n * 4, "{width:?} must beat f32");
        }
    }
}
