//! COMQ-style baseline (Zhang et al. 2025): backpropagation-free cyclic
//! coordinate descent on the layer objective ‖X(w − v)‖² with each
//! coordinate constrained to a *fixed* per-channel min-max grid.
//!
//! The contrast with Beacon is exactly the paper's point: COMQ's grid
//! (scale) is chosen once up front from min/max, Beacon's scale emerges
//! from the optimization itself. Per-layer bit widths / sweep counts
//! arrive through the [`crate::quant::engine::ComqQuantizer`] the
//! pipeline builds from each [`crate::config::QuantPlan`] entry.

use crate::linalg::matrix::axpy;
use crate::linalg::Matrix;

use super::alphabet::{levels, BitWidth};
use super::rtn::{minmax_scale, rtn_channel};

pub const EPS: f64 = 1e-12;

/// Quantize a layer with COMQ. Returns the dequantized weights.
/// Channel fan-out width comes from the environment (0 = auto); see
/// [`comq_layer_threads`] for an explicit budget.
pub fn comq_layer(x: &Matrix, w: &Matrix, bits: BitWidth, loops: usize) -> Matrix {
    comq_layer_threads(x, w, bits, loops, 0)
}

/// [`comq_layer`] with an explicit channel thread budget (0 = auto).
/// Bit-identical at any thread count — channels are independent and
/// gathered in index order.
pub fn comq_layer_threads(
    x: &Matrix,
    w: &Matrix,
    bits: BitWidth,
    loops: usize,
    threads: usize,
) -> Matrix {
    let (n, np) = (w.rows, w.cols);
    let g = x.gram(); // G = XᵀX
    let g_cols = g.columns();
    let gdiag: Vec<f64> = (0..n)
        .map(|i| if g[(i, i)] > EPS { g[(i, i)] } else { 1.0 })
        .collect();
    let lv = levels(bits);

    let w_cols = w.columns();
    let nthreads = crate::util::pool::resolve_threads(threads);
    let cols = crate::util::pool::par_map_labeled("engine.channels", np, nthreads, |j| {
        let wj = &w_cols[j];
        let (c, z) = minmax_scale(wj, bits);
        let grid: Vec<f64> = (0..lv).map(|k| c * (k as f64 + z)).collect();
        let mut v = rtn_channel(wj, bits);
        // residual gradient r = G (w − v)
        let diff: Vec<f64> = wj.iter().zip(&v).map(|(a, b)| a - b).collect();
        let mut r = g.matvec(&diff);
        for _ in 0..loops {
            for t in 0..n {
                let opt = v[t] + r[t] / gdiag[t];
                // nearest grid element (grid is ascending)
                let mut best = grid[0];
                let mut bd = f64::INFINITY;
                for &gv in &grid {
                    let d = (gv - opt).abs();
                    if d < bd {
                        bd = d;
                        best = gv;
                    }
                }
                if best != v[t] {
                    axpy(-(best - v[t]), &g_cols[t], &mut r);
                    v[t] = best;
                }
            }
        }
        v
    });

    let mut out = Matrix::zeros(n, np);
    for (j, col) in cols.iter().enumerate() {
        out.set_col(j, col);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::metrics::layer_recon_error;
    use crate::quant::rtn::rtn_layer;
    use crate::util::prop::{prop_check, Gen};

    fn case(g: &mut Gen, m: usize, n: usize, np: usize) -> (Matrix, Matrix) {
        let x = Matrix::from_vec(m, n, g.vec_normal(m * n, 1.0));
        let w = Matrix::from_vec(n, np, g.vec_normal(n * np, 0.25));
        (x, w)
    }

    #[test]
    fn never_worse_than_rtn() {
        // COMQ starts from RTN and each accepted move reduces the
        // quadratic objective, so it can only improve.
        prop_check(10, |g| {
            let (x, w) = case(g, 80, 10, 5);
            for bits in [BitWidth::B2, BitWidth::B3] {
                let e_rtn = layer_recon_error(&x, &w, &rtn_layer(&w, bits));
                let e_cq =
                    layer_recon_error(&x, &w, &comq_layer(&x, &w, bits, 3));
                if e_cq > e_rtn + 1e-9 {
                    return Err(format!("comq {e_cq} worse than rtn {e_rtn}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn loops_monotone_improvement() {
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(1) };
        let (x, w) = case(&mut g, 80, 12, 4);
        let mut prev = f64::INFINITY;
        for loops in [0usize, 1, 2, 4] {
            let e = layer_recon_error(&x, &w, &comq_layer(&x, &w, BitWidth::B2, loops));
            assert!(e <= prev + 1e-9, "loops {loops}: {e} > {prev}");
            prev = e;
        }
    }

    #[test]
    fn outputs_on_fixed_grid() {
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(2) };
        let (x, w) = case(&mut g, 64, 8, 3);
        let q = comq_layer(&x, &w, BitWidth::B2, 3);
        for j in 0..3 {
            let col = w.col(j);
            let (c, z) = minmax_scale(&col, BitWidth::B2);
            for i in 0..8 {
                let k = (q[(i, j)] / c - z).round();
                assert!((q[(i, j)] - c * (k + z)).abs() < 1e-9);
                assert!((0.0..=3.0).contains(&k));
            }
        }
    }

    #[test]
    fn zero_loops_is_rtn() {
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(3) };
        let (x, w) = case(&mut g, 64, 8, 3);
        let q = comq_layer(&x, &w, BitWidth::B2, 0);
        let rtn = rtn_layer(&w, BitWidth::B2);
        for (a, b) in q.data.iter().zip(&rtn.data) {
            assert!((a - b).abs() < 1e-12);
        }
    }
}
