//! COMQ-style baseline (Zhang et al. 2025): backpropagation-free cyclic
//! coordinate descent on the layer objective ‖X(w − v)‖² with each
//! coordinate constrained to a *fixed* per-channel min-max grid.
//!
//! The contrast with Beacon is exactly the paper's point: COMQ's grid
//! (scale) is chosen once up front from min/max, Beacon's scale emerges
//! from the optimization itself. Per-layer bit widths / sweep counts
//! arrive through the [`crate::quant::engine::ComqQuantizer`] the
//! pipeline builds from each [`crate::config::QuantPlan`] entry.

use crate::linalg::matrix::axpy;
use crate::linalg::Matrix;

use super::alphabet::{levels, BitWidth};
use super::engine::LayerQuant;
use super::rtn::{minmax_scale, nearest_level, rtn_channel};
use super::scenario::{assemble_layer, split_outliers, ChannelQuant, Scenario};

pub const EPS: f64 = 1e-12;

/// Quantize a layer with COMQ. Returns the dequantized weights.
/// Channel fan-out width comes from the environment (0 = auto); see
/// [`comq_layer_threads`] for an explicit budget.
pub fn comq_layer(x: &Matrix, w: &Matrix, bits: BitWidth, loops: usize) -> Matrix {
    comq_layer_threads(x, w, bits, loops, 0)
}

/// [`comq_layer`] with an explicit channel thread budget (0 = auto).
/// Bit-identical at any thread count — channels are independent and
/// gathered in index order.
pub fn comq_layer_threads(
    x: &Matrix,
    w: &Matrix,
    bits: BitWidth,
    loops: usize,
    threads: usize,
) -> Matrix {
    let (n, np) = (w.rows, w.cols);
    let g = x.gram(); // G = XᵀX
    let g_cols = g.columns();
    let gdiag: Vec<f64> = (0..n)
        .map(|i| if g[(i, i)] > EPS { g[(i, i)] } else { 1.0 })
        .collect();
    let lv = levels(bits);

    let w_cols = w.columns();
    let nthreads = crate::util::pool::resolve_threads(threads);
    let cols = crate::util::pool::par_map_labeled("engine.channels", np, nthreads, |j| {
        let wj = &w_cols[j];
        let (c, z) = minmax_scale(wj, bits);
        let grid: Vec<f64> = (0..lv).map(|k| c * (k as f64 + z)).collect();
        let mut v = rtn_channel(wj, bits);
        // residual gradient r = G (w − v)
        let diff: Vec<f64> = wj.iter().zip(&v).map(|(a, b)| a - b).collect();
        let mut r = g.matvec(&diff);
        for _ in 0..loops {
            for t in 0..n {
                let opt = v[t] + r[t] / gdiag[t];
                // nearest grid element (grid is ascending)
                let mut best = grid[0];
                let mut bd = f64::INFINITY;
                for &gv in &grid {
                    let d = (gv - opt).abs();
                    if d < bd {
                        bd = d;
                        best = gv;
                    }
                }
                if best != v[t] {
                    axpy(-(best - v[t]), &g_cols[t], &mut r);
                    v[t] = best;
                }
            }
        }
        v
    });

    let mut out = Matrix::zeros(n, np);
    for (j, col) in cols.iter().enumerate() {
        out.set_col(j, col);
    }
    out
}

/// COMQ under a grouped / outlier-split [`Scenario`]: the cyclic descent
/// still runs over the *whole* channel (the Gram coupling crosses group
/// boundaries), but each coordinate is constrained to its own group's
/// min-max grid (computed over the group's non-outlier members), and
/// outlier coordinates are fixed at their exact weight from the start —
/// they contribute zero residual and are skipped by the update loop.
/// Bit-identical at any thread count, like [`comq_layer_threads`].
pub fn comq_layer_scenario(
    x: &Matrix,
    w: &Matrix,
    bits: BitWidth,
    loops: usize,
    threads: usize,
    sc: &Scenario,
) -> LayerQuant {
    let (n, np) = (w.rows, w.cols);
    let g = x.gram(); // G = XᵀX
    let g_cols = g.columns();
    let gdiag: Vec<f64> = (0..n)
        .map(|i| if g[(i, i)] > EPS { g[(i, i)] } else { 1.0 })
        .collect();
    let lv = levels(bits);
    let bounds = sc.group_bounds(n);
    let mut gidx = vec![0usize; n];
    for (gi, &(lo, hi)) in bounds.iter().enumerate() {
        for t in lo..hi {
            gidx[t] = gi;
        }
    }

    let w_cols = w.columns();
    let nthreads = crate::util::pool::resolve_threads(threads);
    let results = crate::util::pool::par_map_labeled("engine.channels", np, nthreads, |j| {
        let wj = &w_cols[j];
        let outl = split_outliers(wj, sc.outlier_k);
        let mut cz = Vec::with_capacity(bounds.len());
        for &(lo, hi) in &bounds {
            let members: Vec<f64> = (lo..hi)
                .filter(|t| outl.binary_search(t).is_err())
                .map(|t| wj[t])
                .collect();
            cz.push(if members.is_empty() { (1.0, 0.0) } else { minmax_scale(&members, bits) });
        }
        let grids: Vec<Vec<f64>> = cz
            .iter()
            .map(|&(c, z)| (0..lv).map(|k| c * (k as f64 + z)).collect())
            .collect();
        // init: per-group RTN for members, exact weight for outliers
        let mut v: Vec<f64> = (0..n)
            .map(|t| {
                if outl.binary_search(&t).is_ok() {
                    wj[t]
                } else {
                    let (c, z) = cz[gidx[t]];
                    c * (nearest_level(wj[t], c, z, lv) as f64 + z)
                }
            })
            .collect();
        let diff: Vec<f64> = wj.iter().zip(&v).map(|(a, b)| a - b).collect();
        let mut r = g.matvec(&diff);
        for _ in 0..loops {
            for t in 0..n {
                if outl.binary_search(&t).is_ok() {
                    continue; // fixed at the exact weight
                }
                let opt = v[t] + r[t] / gdiag[t];
                let grid = &grids[gidx[t]];
                let mut best = grid[0];
                let mut bd = f64::INFINITY;
                for &gv in grid {
                    let d = (gv - opt).abs();
                    if d < bd {
                        bd = d;
                        best = gv;
                    }
                }
                if best != v[t] {
                    axpy(-(best - v[t]), &g_cols[t], &mut r);
                    v[t] = best;
                }
            }
        }
        let codes: Vec<f64> = (0..n)
            .map(|t| {
                let (c, z) = cz[gidx[t]];
                if outl.binary_search(&t).is_ok() {
                    // on-grid dummy: the group's nearest level
                    nearest_level(wj[t], c, z, lv) as f64
                } else {
                    (v[t] / c - z).round().clamp(0.0, (lv - 1) as f64)
                }
            })
            .collect();
        ChannelQuant {
            codes,
            groups: cz.iter().map(|&(c, z)| (c, c * z)).collect(),
            outliers: outl.iter().map(|&t| (t, wj[t])).collect(),
            dequant: v,
        }
    });
    assemble_layer(n, results, sc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::metrics::layer_recon_error;
    use crate::quant::rtn::rtn_layer;
    use crate::util::prop::{prop_check, Gen};

    fn case(g: &mut Gen, m: usize, n: usize, np: usize) -> (Matrix, Matrix) {
        let x = Matrix::from_vec(m, n, g.vec_normal(m * n, 1.0));
        let w = Matrix::from_vec(n, np, g.vec_normal(n * np, 0.25));
        (x, w)
    }

    #[test]
    fn never_worse_than_rtn() {
        // COMQ starts from RTN and each accepted move reduces the
        // quadratic objective, so it can only improve.
        prop_check(10, |g| {
            let (x, w) = case(g, 80, 10, 5);
            for bits in [BitWidth::B2, BitWidth::B3] {
                let e_rtn = layer_recon_error(&x, &w, &rtn_layer(&w, bits));
                let e_cq =
                    layer_recon_error(&x, &w, &comq_layer(&x, &w, bits, 3));
                if e_cq > e_rtn + 1e-9 {
                    return Err(format!("comq {e_cq} worse than rtn {e_rtn}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn loops_monotone_improvement() {
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(1) };
        let (x, w) = case(&mut g, 80, 12, 4);
        let mut prev = f64::INFINITY;
        for loops in [0usize, 1, 2, 4] {
            let e = layer_recon_error(&x, &w, &comq_layer(&x, &w, BitWidth::B2, loops));
            assert!(e <= prev + 1e-9, "loops {loops}: {e} > {prev}");
            prev = e;
        }
    }

    #[test]
    fn outputs_on_fixed_grid() {
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(2) };
        let (x, w) = case(&mut g, 64, 8, 3);
        let q = comq_layer(&x, &w, BitWidth::B2, 3);
        for j in 0..3 {
            let col = w.col(j);
            let (c, z) = minmax_scale(&col, BitWidth::B2);
            for i in 0..8 {
                let k = (q[(i, j)] / c - z).round();
                assert!((q[(i, j)] - c * (k + z)).abs() < 1e-9);
                assert!((0.0..=3.0).contains(&k));
            }
        }
    }

    #[test]
    fn scenario_outliers_exact_and_codes_on_group_grids() {
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(7) };
        let (x, mut w) = case(&mut g, 64, 40, 3);
        // plant a dominating outlier in channel 1
        w[(5, 1)] = 9.0;
        let sc = Scenario { group_size: 16, outlier_k: 1, ..Scenario::default() };
        let lq = comq_layer_scenario(&x, &w, BitWidth::B2, 3, 1, &sc);
        let meta = lq.grouped.as_ref().expect("scenario metadata");
        assert_eq!(meta.group_size, 16);
        for j in 0..3 {
            assert_eq!(meta.groups[j].len(), 3, "40 rows / g16 = 3 groups");
            assert_eq!(meta.outliers[j].len(), 1);
            let (row, val) = meta.outliers[j][0];
            assert_eq!(lq.dequant[(row, j)], val, "outlier kept exact");
            // non-outlier values decode from their group's (scale, offset)
            for i in 0..40 {
                if i == row {
                    continue;
                }
                let (c, off) = meta.groups[j][i / 16];
                let rebuilt = c * lq.codes[j][i] + off;
                assert!((rebuilt - lq.dequant[(i, j)]).abs() < 1e-9);
            }
        }
        assert_eq!(meta.outliers[1][0], (5, 9.0));
        // thread invariance of the scenario path
        let lq4 = comq_layer_scenario(&x, &w, BitWidth::B2, 3, 4, &sc);
        for (a, b) in lq.dequant.data.iter().zip(&lq4.dequant.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn zero_loops_is_rtn() {
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(3) };
        let (x, w) = case(&mut g, 64, 8, 3);
        let q = comq_layer(&x, &w, BitWidth::B2, 0);
        let rtn = rtn_layer(&w, BitWidth::B2);
        for (a, b) in q.data.iter().zip(&rtn.data) {
            assert!((a - b).abs() < 1e-12);
        }
    }
}
