//! Round-to-nearest on the asymmetric per-channel min-max grid — the
//! baseline quantizer Q of paper §1 and the initializer for COMQ. The
//! grid width is a per-call argument, so a [`crate::config::QuantPlan`]
//! can assign a different width to every layer's
//! [`crate::quant::engine::RtnQuantizer`].

use crate::linalg::Matrix;

use super::alphabet::{levels, BitWidth};
use super::scenario::{split_outliers, ChannelQuant, Scenario};

pub const EPS: f64 = 1e-12;

/// Per-channel min-max grid: (scale c, zero point z) with grid
/// {c·(z+k) : k = 0..levels−1}.
pub fn minmax_scale(w: &[f64], bits: BitWidth) -> (f64, f64) {
    let lv = levels(bits) as f64;
    let lo = w.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = w.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let c = (hi - lo) / (lv - 1.0);
    if c <= EPS {
        return (1.0, 0.0);
    }
    (c, lo / c)
}

/// Index of the nearest grid level for value `v` on grid (c, z).
#[inline]
pub fn nearest_level(v: f64, c: f64, z: f64, lv: usize) -> usize {
    let k = (v / c - z).round();
    k.clamp(0.0, (lv - 1) as f64) as usize
}

/// RTN one channel; returns the dequantized values.
pub fn rtn_channel(w: &[f64], bits: BitWidth) -> Vec<f64> {
    let lv = levels(bits);
    let (c, z) = minmax_scale(w, bits);
    w.iter()
        .map(|v| c * (nearest_level(*v, c, z, lv) as f64 + z))
        .collect()
}

/// RTN one channel under a grouped / outlier-split [`Scenario`]: the
/// top-k magnitude weights stay exact (sidecar), every group gets its
/// own min-max grid over its non-outlier members, codes round per
/// group. Outlier slots carry their group's nearest level as an
/// on-grid dummy code; `dequant` holds the exact weight there.
///
/// With `group_size = 0` and `outlier_k = 0` this degenerates to one
/// group with exactly [`rtn_channel`]'s grid and values.
pub fn rtn_channel_scenario(w: &[f64], bits: BitWidth, sc: &Scenario) -> ChannelQuant {
    let lv = levels(bits);
    let outl = split_outliers(w, sc.outlier_k);
    let bounds = sc.group_bounds(w.len());
    let mut cz = Vec::with_capacity(bounds.len());
    for &(lo, hi) in &bounds {
        let members: Vec<f64> = (lo..hi)
            .filter(|t| outl.binary_search(t).is_err())
            .map(|t| w[t])
            .collect();
        // a group fully consumed by outliers keeps the degenerate grid
        cz.push(if members.is_empty() { (1.0, 0.0) } else { minmax_scale(&members, bits) });
    }
    let mut codes = vec![0.0; w.len()];
    let mut dequant = vec![0.0; w.len()];
    for (gi, &(lo, hi)) in bounds.iter().enumerate() {
        let (c, z) = cz[gi];
        for t in lo..hi {
            let k = nearest_level(w[t], c, z, lv) as f64;
            codes[t] = k;
            dequant[t] = if outl.binary_search(&t).is_ok() {
                w[t] // exact sidecar value; the code is an on-grid dummy
            } else {
                c * (k + z)
            };
        }
    }
    ChannelQuant {
        codes,
        groups: cz.iter().map(|&(c, z)| (c, c * z)).collect(),
        outliers: outl.iter().map(|&t| (t, w[t])).collect(),
        dequant,
    }
}

/// RTN a whole layer (channels = columns), serial path.
pub fn rtn_layer(w: &Matrix, bits: BitWidth) -> Matrix {
    rtn_layer_threads(w, bits, 1)
}

/// RTN a whole layer fanning independent channels over `threads` workers
/// (0 = auto). Bit-identical to [`rtn_layer`] at any thread count — the
/// pool gathers channels in index order.
pub fn rtn_layer_threads(w: &Matrix, bits: BitWidth, threads: usize) -> Matrix {
    let nthreads = crate::util::pool::resolve_threads(threads);
    let w_cols = w.columns();
    let cols = crate::util::pool::par_map_labeled("engine.channels", w.cols, nthreads, |j| {
        rtn_channel(&w_cols[j], bits)
    });
    let mut out = Matrix::zeros(w.rows, w.cols);
    for (j, col) in cols.iter().enumerate() {
        out.set_col(j, col);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn idempotent_on_grid() {
        prop_check(20, |g| {
            let w = g.vec_normal(16, 0.5);
            let q = rtn_channel(&w, BitWidth::B3);
            let q2 = rtn_channel(&q, BitWidth::B3);
            for (a, b) in q.iter().zip(&q2) {
                if (a - b).abs() > 1e-9 {
                    return Err(format!("not idempotent: {a} vs {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn preserves_extremes() {
        prop_check(20, |g| {
            let w = g.vec_normal(16, 0.5);
            let q = rtn_channel(&w, BitWidth::B2);
            let wmin = w.iter().cloned().fold(f64::INFINITY, f64::min);
            let wmax = w.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let qmin = q.iter().cloned().fold(f64::INFINITY, f64::min);
            let qmax = q.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            if (wmin - qmin).abs() > 1e-9 || (wmax - qmax).abs() > 1e-9 {
                return Err("extremes moved".into());
            }
            Ok(())
        });
    }

    #[test]
    fn error_bounded_by_half_step() {
        prop_check(20, |g| {
            let w = g.vec_normal(20, 0.5);
            let (c, _) = minmax_scale(&w, BitWidth::B3);
            let q = rtn_channel(&w, BitWidth::B3);
            for (a, b) in w.iter().zip(&q) {
                if (a - b).abs() > c / 2.0 + 1e-9 {
                    return Err(format!("error {} > c/2 {}", (a - b).abs(), c / 2.0));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn scenario_degenerates_to_rtn_channel() {
        prop_check(20, |g| {
            let w = g.vec_normal(24, 0.5);
            let dense = rtn_channel(&w, BitWidth::B3);
            let sc = Scenario::default();
            let ch = rtn_channel_scenario(&w, BitWidth::B3, &sc);
            assert_eq!(ch.groups.len(), 1);
            assert!(ch.outliers.is_empty());
            for (a, b) in dense.iter().zip(&ch.dequant) {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("dense mismatch: {a} vs {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn scenario_groups_never_hurt_and_outliers_exact() {
        prop_check(20, |g| {
            let mut w = g.vec_normal(40, 0.5);
            w[7] = 12.0 + w[7].abs(); // plant a dominating outlier
            let sc = Scenario { group_size: 16, outlier_k: 1, ..Scenario::default() };
            let ch = rtn_channel_scenario(&w, BitWidth::B2, &sc);
            assert_eq!(ch.groups.len(), 3, "ragged tail group");
            assert_eq!(ch.outliers, vec![(7, w[7])]);
            assert_eq!(ch.dequant[7], w[7], "outlier kept exact");
            let dense: f64 = rtn_channel(&w, BitWidth::B2)
                .iter()
                .zip(&w)
                .map(|(q, v)| (q - v) * (q - v))
                .sum();
            let grouped: f64 =
                ch.dequant.iter().zip(&w).map(|(q, v)| (q - v) * (q - v)).sum();
            if grouped > dense + 1e-12 {
                return Err(format!("grouped+outlier {grouped} worse than dense {dense}"));
            }
            // codes live on each group's grid
            let lv = levels(BitWidth::B2) as f64;
            for &k in &ch.codes {
                if k < 0.0 || k > lv - 1.0 || k.fract() != 0.0 {
                    return Err(format!("off-grid code {k}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn constant_channel() {
        let w = vec![0.7; 8];
        let q = rtn_channel(&w, BitWidth::B2);
        assert!(q.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn level_count_respected() {
        prop_check(10, |g| {
            let w = g.vec_normal(64, 0.5);
            let q = rtn_channel(&w, BitWidth::B2);
            let mut uniq: Vec<i64> = q.iter().map(|v| (v * 1e9).round() as i64).collect();
            uniq.sort_unstable();
            uniq.dedup();
            if uniq.len() > 4 {
                return Err(format!("{} distinct levels at 2-bit", uniq.len()));
            }
            Ok(())
        });
    }
}
