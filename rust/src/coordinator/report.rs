//! Text table rendering for the experiment reports (EXPERIMENTS.md rows).

pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("## {}\n\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for i in 0..ncols {
                line.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }
}

pub fn pct(v: f64) -> String {
    format!("{:.2}", v * 100.0)
}

/// Compact probe-cell tag: `method:bits` plus the scenario suffix
/// (`+gN`, `+kN`) when the cell is not the dense per-channel default —
/// keeps planner-table columns distinct across the scenario grid.
fn probe_tag(c: &super::planner::ProbeCell) -> String {
    let mut s = format!("{}:{}", c.method.name(), c.bits.label());
    if c.group_size > 0 {
        s.push_str(&format!("+g{}", c.group_size));
    }
    if c.outlier_k > 0 {
        s.push_str(&format!("+k{}", c.outlier_k));
    }
    s
}

/// Render a [`PlannerReport`](super::planner::PlannerReport) — the
/// sibling of [`plan_table`] for searched plans: one row per layer with
/// the full probe error matrix (columns in candidate order) and the
/// chosen `(method, bits)`; budget utilization in the title.
pub fn planner_table(p: &super::planner::PlannerReport) -> Table {
    let mut headers: Vec<String> = vec!["layer".into(), "numel".into()];
    if let Some(first) = p.layers.first() {
        for c in &first.probes {
            headers.push(probe_tag(c));
        }
    }
    headers.push("chosen".into());
    let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        &format!(
            "auto-plan search — budget {:.2} bits, chosen {:.3} ({:.0}% used), {} probes, {}/{} upgrades",
            p.budget_bits,
            p.effective_bits,
            100.0 * p.budget_utilization(),
            p.probe_count,
            p.upgrades_applied,
            p.upgrades_total,
        ),
        &header_refs,
    );
    for lr in &p.layers {
        let mut cells = vec![lr.layer.clone(), lr.numel.to_string()];
        for c in &lr.probes {
            cells.push(format!("{:.4}", c.error));
        }
        cells.push(format!("{} ({:.4})", probe_tag(&lr.chosen), lr.chosen.error));
        t.row(cells);
    }
    t
}

/// Render a [`QuantReport`]'s per-layer plan rows — which method/bits
/// each layer got and the reconstruction error it achieved — plus the
/// size-weighted effective-bits summary in the title.
pub fn plan_table(r: &super::pipeline::QuantReport) -> Table {
    let mut t = Table::new(
        &format!(
            "{} — {:.2} effective bits/weight",
            r.label, r.effective_bits
        ),
        &["layer", "method", "bits", "recon err"],
    );
    for row in &r.layers {
        t.row(vec![
            row.layer.clone(),
            row.method.name().to_string(),
            row.bits.label(),
            format!("{:.4}", row.error),
        ]);
    }
    t
}

fn fmt_bytes(b: u64) -> String {
    if b >= 1 << 20 {
        format!("{:.1} MiB", b as f64 / (1u64 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.1} KiB", b as f64 / 1024.0)
    } else {
        format!("{b} B")
    }
}

/// Render a [`MetricsReport`](crate::obs::MetricsReport) — the
/// recorder-derived metrics section of a traced run, one row per
/// metric, styled like the planner table.
pub fn metrics_table(m: &crate::obs::MetricsReport) -> Table {
    let mut t = Table::new("run metrics (--trace)", &["metric", "value"]);
    for (name, secs) in &m.phases {
        t.row(vec![format!("{name} wall"), format!("{secs:.3} s")]);
    }
    if let Some(u) = m.worker_utilization {
        t.row(vec![
            format!("worker utilization ({} workers)", m.workers),
            format!("{:.0}%", 100.0 * u),
        ]);
    }
    if let Some(rate) = m.gram_cache_hit_rate() {
        t.row(vec![
            "gram cache hit rate".to_string(),
            format!(
                "{:.0}% ({} hit / {} miss)",
                100.0 * rate,
                m.gram_cache_hits,
                m.gram_cache_misses
            ),
        ]);
    }
    if let Some(h) = &m.channel_ns {
        t.row(vec![
            format!("per-channel ns (n={})", h.count),
            format!(
                "min {} / p50 {} / p95 {} / p99 {} / max {} / mean {}",
                h.min, h.p50, h.p95, h.p99, h.max, h.mean
            ),
        ]);
    }
    if m.io_read_bytes > 0 || m.io_write_bytes > 0 {
        t.row(vec![
            "store I/O".to_string(),
            format!(
                "read {} / write {}",
                fmt_bytes(m.io_read_bytes),
                fmt_bytes(m.io_write_bytes)
            ),
        ]);
    }
    t.row(vec![
        "recorder threads seen".to_string(),
        m.threads_seen.to_string(),
    ]);
    t
}

/// Render a [`MemoryReport`](crate::obs::MemoryReport) — the heap
/// section of a traced run: allocator totals, per-phase deltas,
/// registered resident footprints and the packed-vs-f32 ratio.
pub fn memory_table(m: &crate::obs::MemoryReport) -> Table {
    let mut t = Table::new("memory (--trace)", &["metric", "value"]);
    if !m.tracking {
        t.row(vec![
            "heap tracking".to_string(),
            "off (run a binary with TrackingAlloc installed)".to_string(),
        ]);
    } else {
        t.row(vec![
            "heap peak / live".to_string(),
            format!(
                "{} / {}",
                fmt_bytes(m.stats.peak_bytes),
                fmt_bytes(m.stats.live_bytes)
            ),
        ]);
        t.row(vec![
            "allocations".to_string(),
            format!(
                "{} allocs / {} frees ({} allocated)",
                m.stats.allocs,
                m.stats.deallocs,
                fmt_bytes(m.stats.alloc_bytes)
            ),
        ]);
        for p in &m.phases {
            let sign = if p.net_bytes < 0 { "-" } else { "+" };
            t.row(vec![
                format!("{} heap", p.name),
                format!(
                    "net {}{} / peak {}",
                    sign,
                    fmt_bytes(p.net_bytes.unsigned_abs()),
                    fmt_bytes(p.peak_bytes)
                ),
            ]);
        }
    }
    for (name, bytes) in &m.resident {
        t.row(vec![format!("{name} resident"), fmt_bytes(*bytes)]);
    }
    if let Some(pf) = &m.packed {
        t.row(vec![
            "packed weights vs f32".to_string(),
            format!(
                "{} / {} = {:.2}% (theoretical {:.2}%, +{} metadata)",
                fmt_bytes(pf.payload_bytes),
                fmt_bytes(pf.fp_bytes),
                100.0 * pf.ratio(),
                100.0 * pf.theoretical_ratio,
                fmt_bytes(pf.meta_bytes)
            ),
        ]);
    }
    t
}

fn fmt_ms(ns: u64) -> String {
    format!("{:.3} ms", ns as f64 / 1e6)
}

/// Render a [`ServeReport`](crate::serve::ServeReport) — the serving
/// scoreboard: latency/queue-wait/service quantiles, throughput, batch
/// shape, and peak heap — styled like the other report tables.
pub fn serve_table(r: &crate::serve::ServeReport) -> Table {
    let mut t = Table::new(
        &format!(
            "{} — {} requests in {} batches, {:.1} req/s",
            r.label,
            r.requests,
            r.batches,
            r.requests_per_sec()
        ),
        &["metric", "value"],
    );
    t.row(vec![
        "workers × gemm threads".to_string(),
        format!("{} × {}", r.workers, r.gemm_threads),
    ]);
    t.row(vec![
        "batcher".to_string(),
        format!(
            "max {} / {:.1} ms deadline / queue cap {}",
            r.max_batch, r.deadline_ms, r.queue_capacity
        ),
    ]);
    for (name, h) in [
        ("latency", &r.latency_ns),
        ("queue wait", &r.queue_wait_ns),
        ("service", &r.service_ns),
    ] {
        t.row(vec![
            format!("{name} (n={})", h.count),
            format!(
                "min {} / p50 {} / p95 {} / p99 {} / max {}",
                fmt_ms(h.min),
                fmt_ms(h.p50),
                fmt_ms(h.p95),
                fmt_ms(h.p99),
                fmt_ms(h.max)
            ),
        ]);
    }
    let dist = r
        .batch_sizes
        .iter()
        .map(|(size, count)| format!("{size}×{count}"))
        .collect::<Vec<_>>()
        .join(" ");
    t.row(vec![
        format!("batch sizes (mean {:.2})", r.mean_batch()),
        if dist.is_empty() { "-".to_string() } else { dist },
    ]);
    if r.peak_heap_bytes > 0 {
        t.row(vec![
            "peak heap".to_string(),
            fmt_bytes(r.peak_heap_bytes),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_markdown() {
        let mut t = Table::new("Demo", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["333".into(), "4".into()]);
        let s = t.render();
        assert!(s.contains("## Demo"));
        assert!(s.contains("| a   | bb |"));
        assert!(s.contains("| 333 | 4  |"));
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn pct_format() {
        assert_eq!(pct(0.87654), "87.65");
    }

    #[test]
    fn planner_table_renders_probe_matrix() {
        use crate::config::Method;
        use crate::coordinator::planner::{LayerProbeReport, PlannerReport, ProbeCell};
        use crate::quant::alphabet::BitWidth;
        let c2 = ProbeCell {
            method: Method::Beacon,
            bits: BitWidth::B2,
            group_size: 16,
            outlier_k: 2,
            error: 0.4321,
        };
        let c4 = ProbeCell {
            method: Method::Comq,
            bits: BitWidth::B4,
            group_size: 0,
            outlier_k: 0,
            error: 0.1111,
        };
        let p = PlannerReport {
            budget_bits: 3.0,
            probe_count: 2,
            layers: vec![LayerProbeReport {
                layer: "blocks.0.qkv.w".into(),
                numel: 12288,
                probes: vec![c2, c4],
                chosen: c4,
            }],
            effective_bits: 3.0,
            floor_bits: 2.0,
            upgrades_applied: 1,
            upgrades_total: 1,
        };
        let s = planner_table(&p).render();
        assert!(s.contains("budget 3.00 bits"), "{s}");
        assert!(s.contains("100% used"), "{s}");
        assert!(s.contains("beacon:2-bit+g16+k2"), "{s}");
        assert!(s.contains("0.4321"), "{s}");
        assert!(s.contains("comq:4-bit (0.1111)"), "{s}");
        assert!(s.contains("12288"), "{s}");
    }

    #[test]
    fn plan_table_renders_rows() {
        use crate::config::Method;
        use crate::coordinator::pipeline::{LayerReport, QuantReport};
        use crate::quant::alphabet::BitWidth;
        let r = QuantReport {
            label: "demo".into(),
            fp_top1: 0.9,
            top1: 0.8,
            layers: vec![LayerReport {
                layer: "blocks.0.qkv.w".into(),
                method: Method::Beacon,
                bits: BitWidth::B2,
                error: 0.1234,
            }],
            effective_bits: 2.5,
            quantize_secs: 0.0,
            ln_tune_secs: 0.0,
            eval_secs: 0.0,
            ln_tune_losses: Vec::new(),
            planner: None,
            metrics: None,
            memory: None,
        };
        let s = plan_table(&r).render();
        assert!(s.contains("beacon"), "{s}");
        assert!(s.contains("2-bit"), "{s}");
        assert!(s.contains("0.1234"), "{s}");
        assert!(s.contains("2.50 effective bits"), "{s}");
    }

    #[test]
    fn metrics_table_renders_sections() {
        use crate::obs::{HistSummary, MetricsReport};
        let m = MetricsReport {
            phases: vec![("quantize".to_string(), 1.25), ("eval".to_string(), 0.5)],
            worker_utilization: Some(0.82),
            workers: 4,
            gram_cache_hits: 6,
            gram_cache_misses: 6,
            io_read_bytes: 2048,
            io_write_bytes: 3 << 20,
            channel_ns: Some(HistSummary {
                count: 100,
                p50: 96,
                p95: 192,
                p99: 384,
                mean: 120,
                min: 64,
                max: 512,
            }),
            threads_seen: 5,
        };
        let s = metrics_table(&m).render();
        assert!(s.contains("quantize wall"), "{s}");
        assert!(s.contains("1.250 s"), "{s}");
        assert!(s.contains("worker utilization (4 workers)"), "{s}");
        assert!(s.contains("82%"), "{s}");
        assert!(s.contains("50% (6 hit / 6 miss)"), "{s}");
        assert!(
            s.contains("min 64 / p50 96 / p95 192 / p99 384 / max 512 / mean 120"),
            "{s}"
        );
        assert!(s.contains("2.0 KiB"), "{s}");
        assert!(s.contains("3.0 MiB"), "{s}");
    }

    #[test]
    fn memory_table_renders_tracked_run() {
        use crate::obs::memory::{PackedFootprint, PhaseMem};
        use crate::obs::{MemStats, MemoryReport};
        let m = MemoryReport {
            tracking: true,
            stats: MemStats {
                live_bytes: 10 << 20,
                peak_bytes: 90 << 20,
                allocs: 1_000,
                deallocs: 900,
                alloc_bytes: 200 << 20,
                freed_bytes: 190 << 20,
            },
            phases: vec![
                PhaseMem {
                    name: "phase.quantize".to_string(),
                    net_bytes: 5 << 20,
                    peak_bytes: 90 << 20,
                },
                PhaseMem {
                    name: "phase.eval".to_string(),
                    net_bytes: -(2 << 20),
                    peak_bytes: 90 << 20,
                },
            ],
            resident: vec![("pipeline.gram_cache".to_string(), 38 << 20)],
            packed: Some(PackedFootprint {
                payload_bytes: 1 << 20,
                meta_bytes: 2048,
                fp_bytes: 16 << 20,
                theoretical_ratio: 2.0 / 32.0,
            }),
        };
        let s = memory_table(&m).render();
        assert!(s.contains("90.0 MiB / 10.0 MiB"), "{s}");
        assert!(s.contains("1000 allocs / 900 frees"), "{s}");
        assert!(s.contains("phase.quantize heap"), "{s}");
        assert!(s.contains("net +5.0 MiB"), "{s}");
        assert!(s.contains("net -2.0 MiB"), "{s}");
        assert!(s.contains("pipeline.gram_cache resident"), "{s}");
        assert!(s.contains("38.0 MiB"), "{s}");
        assert!(s.contains("= 6.25% (theoretical 6.25%"), "{s}");
    }

    #[test]
    fn memory_table_untracked_says_so() {
        use crate::obs::{MemStats, MemoryReport};
        let m = MemoryReport {
            tracking: false,
            stats: MemStats::default(),
            phases: Vec::new(),
            resident: vec![("model.weight_store".to_string(), 4096)],
            packed: None,
        };
        let s = memory_table(&m).render();
        assert!(s.contains("heap tracking"), "{s}");
        assert!(s.contains("off"), "{s}");
        // resident footprints don't need the allocator
        assert!(s.contains("model.weight_store resident"), "{s}");
        assert!(s.contains("4.0 KiB"), "{s}");
    }

    #[test]
    fn serve_table_renders_scoreboard() {
        use crate::obs::HistSummary;
        use crate::serve::ServeReport;
        let h = |p50: u64| HistSummary {
            count: 64,
            p50,
            p95: p50 * 2,
            p99: p50 * 3,
            mean: p50,
            min: p50 / 2,
            max: p50 * 4,
        };
        let r = ServeReport {
            label: "closed 4-bit".into(),
            requests: 64,
            batches: 16,
            wall_secs: 2.0,
            workers: 2,
            gemm_threads: 4,
            max_batch: 8,
            deadline_ms: 2.0,
            queue_capacity: 64,
            latency_ns: h(2_000_000),
            queue_wait_ns: h(500_000),
            service_ns: h(1_000_000),
            batch_sizes: vec![(2, 8), (8, 8)],
            peak_heap_bytes: 3 << 20,
        };
        let s = serve_table(&r).render();
        assert!(s.contains("closed 4-bit — 64 requests in 16 batches"), "{s}");
        assert!(s.contains("32.0 req/s"), "{s}");
        assert!(s.contains("2 × 4"), "{s}");
        assert!(s.contains("max 8 / 2.0 ms deadline / queue cap 64"), "{s}");
        assert!(s.contains("latency (n=64)"), "{s}");
        assert!(s.contains("p50 2.000 ms"), "{s}");
        assert!(s.contains("batch sizes (mean 4.00)"), "{s}");
        assert!(s.contains("2×8 8×8"), "{s}");
        assert!(s.contains("3.0 MiB"), "{s}");
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(42), "42 B");
        assert_eq!(fmt_bytes(1536), "1.5 KiB");
        assert_eq!(fmt_bytes(5 << 20), "5.0 MiB");
    }
}
