//! LayerNorm tuning (paper §3 "Normalization Tuning"): after the whole
//! model is quantized, lightly train ONLY the LN parameters to match the
//! FP model's calibration logits. The gradient step itself is an AOT
//! artifact (`ln_tune_step`); Rust drives the epoch loop and writes the
//! updated parameters back into the store — no Python, no optimizer state.

use anyhow::Result;

use crate::config::QuantConfig;
use crate::model::spec::{ln_param_names, param_spec};
use crate::model::WeightStore;
use crate::runtime::client::{literal_f32, literal_to_f32};
use xla::Literal;

use super::pipeline::Pipeline;

/// Run `qc.ln_tune_steps` SGD steps; returns the per-step distill losses.
pub fn tune(
    pipe: &Pipeline,
    store: &mut WeightStore,
    teacher_logits: &[f32],
    qc: &QuantConfig,
) -> Result<Vec<f32>> {
    let m = &pipe.artifacts.manifest;
    let cfg = &m.cfg;
    let b = m.ln_batch;
    let k = cfg.num_classes;
    anyhow::ensure!(
        pipe.calib.count >= b,
        "calibration set ({}) smaller than LN batch ({b})",
        pipe.calib.count
    );
    let ln_names = ln_param_names(cfg);
    let spec_names: Vec<String> =
        param_spec(cfg).iter().map(|p| p.name.clone()).collect();

    let mut losses = Vec::with_capacity(qc.ln_tune_steps);
    let nchunks = pipe.calib.count / b;
    for step in 0..qc.ln_tune_steps {
        let chunk = step % nchunks;
        let (lo, hi) = (chunk * b, (chunk + 1) * b);

        let mut inputs = Vec::with_capacity(spec_names.len() + 3);
        for t in store.ordered() {
            let dims: Vec<i64> = t.shape.iter().map(|d| *d as i64).collect();
            inputs.push(literal_f32(&t.data, &dims)?);
        }
        inputs.push(literal_f32(
            pipe.calib.batch(lo, hi),
            &[b as i64, cfg.image as i64, cfg.image as i64, cfg.channels as i64],
        )?);
        inputs.push(literal_f32(
            &teacher_logits[lo * k..hi * k],
            &[b as i64, k as i64],
        )?);
        inputs.push(Literal::from(qc.ln_tune_lr));

        let out = pipe.runtime.exec(&m.ln_tune_step, &inputs)?;
        anyhow::ensure!(
            out.len() == 1 + ln_names.len(),
            "ln_tune_step returned {} outputs, expected {}",
            out.len(),
            1 + ln_names.len()
        );
        losses.push(out[0].get_first_element::<f32>()?);
        for (j, name) in ln_names.iter().enumerate() {
            store.set_data(name, literal_to_f32(&out[1 + j])?);
        }
    }
    Ok(losses)
}
