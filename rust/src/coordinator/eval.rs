//! Top-1 evaluation through the `vit_logits` PJRT artifact.
//!
//! The artifact is shape-specialized to `eval_batch` images; the evaluator
//! chunks the eval split, padding the final partial batch (padded logits
//! are ignored).

use anyhow::Result;

use crate::model::WeightStore;
use crate::runtime::client::{literal_f32, literal_to_f32};

use super::pipeline::Pipeline;

/// Top-1 accuracy of `store` on the eval split (first `count` images;
/// 0 = all).
pub fn top1(pipe: &Pipeline, store: &WeightStore, count: usize) -> Result<f64> {
    let m = &pipe.artifacts.manifest;
    let cfg = &m.cfg;
    let ds = &pipe.eval;
    let total = if count == 0 { ds.count } else { count.min(ds.count) };
    anyhow::ensure!(total > 0, "empty eval set");
    let b = m.eval_batch;
    let img_len = ds.shape.len();

    // weight literals once per call
    let mut weight_inputs = Vec::new();
    for t in store.ordered() {
        let dims: Vec<i64> = t.shape.iter().map(|d| *d as i64).collect();
        weight_inputs.push(literal_f32(&t.data, &dims)?);
    }

    let mut correct = 0usize;
    let mut lo = 0usize;
    while lo < total {
        let hi = (lo + b).min(total);
        // build a full batch, padding with the last image if needed
        let mut batch = Vec::with_capacity(b * img_len);
        batch.extend_from_slice(ds.batch(lo, hi));
        while batch.len() < b * img_len {
            batch.extend_from_slice(ds.image(hi - 1));
        }
        let mut inputs = weight_inputs.clone();
        inputs.push(literal_f32(
            &batch,
            &[b as i64, cfg.image as i64, cfg.image as i64, cfg.channels as i64],
        )?);
        let out = pipe.runtime.exec(&m.vit_logits, &inputs)?;
        let logits = literal_to_f32(&out[0])?;
        let k = cfg.num_classes;
        for (bi, item) in (lo..hi).enumerate() {
            let row = &logits[bi * k..(bi + 1) * k];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            if pred as i32 == ds.labels[item] {
                correct += 1;
            }
        }
        lo = hi;
    }
    Ok(correct as f64 / total as f64)
}
