//! Experiment drivers regenerating every table/figure in the paper's
//! evaluation section (DESIGN.md §5 experiment index). Each returns the
//! rendered table AND the raw numbers so benches and EXPERIMENTS.md can
//! both consume them.

use anyhow::Result;

use crate::config::{Method, QuantConfig, SearchSpace};
use crate::linalg::{qr_factor, Matrix};
use crate::quant::alphabet::{alphabet, BitWidth};
use crate::quant::beacon::{beacon_channel, beacon_objective};

use super::pipeline::Pipeline;
use super::report::{pct, Table};

/// Table 1: Beacon variants × bit widths (top-1 %).
pub struct Table1Row {
    pub bits: BitWidth,
    pub loops: usize,
    pub plain: f64,
    pub ec: f64,
    pub centering: f64,
    pub ln: f64,
}

pub fn table1(
    pipe: &mut Pipeline,
    bit_widths: &[(BitWidth, usize)],
) -> Result<(Table, Vec<Table1Row>)> {
    let fp = pipe.fp_top1()?;
    let mut table = Table::new(
        &format!(
            "Table 1 — weight-only quantization of {} with Beacon (FP top-1 {}%)",
            pipe.cfg().name,
            pct(fp)
        ),
        &["bits (K)", "w/o E.C.", "w/ E.C.", "w/ centering", "w/ LN"],
    );
    let mut rows = Vec::new();
    for (bits, loops) in bit_widths {
        let mk = |ec: bool, cent: bool, ln: bool| QuantConfig {
            method: Method::Beacon,
            bits: bits.0,
            loops: *loops,
            error_correction: ec,
            centering: cent,
            ln_tune: ln,
            ..QuantConfig::default()
        };
        let plain = pipe.quantize_cfg(&mk(false, false, false))?.top1;
        let ec = pipe.quantize_cfg(&mk(true, false, false))?.top1;
        let cent = pipe.quantize_cfg(&mk(true, true, false))?.top1;
        let ln = pipe.quantize_cfg(&mk(true, true, true))?.top1;
        table.row(vec![
            format!("{}(K={})", bits.label(), loops),
            pct(plain),
            pct(ec),
            pct(cent),
            pct(ln),
        ]);
        rows.push(Table1Row {
            bits: *bits,
            loops: *loops,
            plain,
            ec,
            centering: cent,
            ln,
        });
    }
    Ok((table, rows))
}

/// Table 2: accuracy drop (%) vs GPTQ and COMQ.
pub struct Table2Row {
    pub bits: BitWidth,
    pub gptq_drop: f64,
    pub comq_drop: f64,
    pub beacon_drop: f64,
}

pub fn table2(
    pipe: &mut Pipeline,
    bit_widths: &[(BitWidth, usize)],
) -> Result<(Table, Vec<Table2Row>)> {
    let fp = pipe.fp_top1()?;
    let mut table = Table::new(
        &format!(
            "Table 2 — accuracy drop (%) on {} (FP top-1 {}%)",
            pipe.cfg().name,
            pct(fp)
        ),
        &["method", "2-bit", "3-bit", "4-bit"],
    );
    let mut drops = vec![Vec::new(), Vec::new(), Vec::new()];
    let mut rows = Vec::new();
    for (bits, loops) in bit_widths {
        let gptq = pipe.quantize_cfg(&QuantConfig {
            method: Method::Gptq,
            bits: bits.0,
            ..QuantConfig::default()
        })?;
        let comq = pipe.quantize_cfg(&QuantConfig {
            method: Method::Comq,
            bits: bits.0,
            loops: *loops,
            ..QuantConfig::default()
        })?;
        // Beacon's Table-2 configuration is the full method (EC+centering)
        let beacon = pipe.quantize_cfg(&QuantConfig {
            method: Method::Beacon,
            bits: bits.0,
            loops: *loops,
            error_correction: true,
            centering: true,
            ..QuantConfig::default()
        })?;
        drops[0].push(gptq.accuracy_drop());
        drops[1].push(comq.accuracy_drop());
        drops[2].push(beacon.accuracy_drop());
        rows.push(Table2Row {
            bits: *bits,
            gptq_drop: gptq.accuracy_drop(),
            comq_drop: comq.accuracy_drop(),
            beacon_drop: beacon.accuracy_drop(),
        });
    }
    for (name, d) in [("GPTQ", &drops[0]), ("COMQ", &drops[1]), ("Beacon", &drops[2])] {
        table.row(
            std::iter::once(name.to_string())
                .chain(d.iter().map(|v| format!("{v:.2}")))
                .collect(),
        );
    }
    Ok((table, rows))
}

/// F1: convergence of the Beacon objective over sweeps ("best results after
/// 4–6 loops", Prop 3.1 monotonicity) — one series per probed layer.
pub fn convergence(pipe: &mut Pipeline, max_loops: usize) -> Result<Table> {
    let store = pipe.weights_fp.clone();
    let (_, acts) = pipe.collect_acts(&store)?;
    let quantizable = pipe.artifacts.manifest.quantizable.clone();
    let headers: Vec<String> = std::iter::once("layer".to_string())
        .chain((0..=max_loops).map(|k| format!("K{k}")))
        .collect();
    let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        "F1 — mean cos∠(Lw, L̃q) per sweep count (greedy init = K0)",
        &header_refs,
    );
    // probe first, middle, last quantizable layers
    let picks = [0, quantizable.len() / 2, quantizable.len() - 1];
    let a = alphabet(BitWidth::B2);
    for &li in &picks {
        let x = &acts[li];
        let w = store.matrix(&quantizable[li]);
        let f = qr_factor(x, x);
        let l_cols = f.l.columns();
        let lt_cols = f.r.columns();
        let nnz: Vec<usize> = (0..w.rows).map(|t| t + 1).collect();
        // average objective over the first 8 channels per sweep count;
        // the probe channels are independent, so fan them over the pool
        // (objectives summed in index order — deterministic).
        let nch = w.cols.min(8);
        let nthreads = crate::util::pool::resolve_threads(0);
        let mut cells = vec![quantizable[li].clone()];
        for loops in 0..=max_loops {
            let objs =
                crate::util::pool::par_map_labeled("engine.channels", nch, nthreads, |j| {
                    let wj = w.col(j);
                    let (q, _) =
                        beacon_channel(&l_cols, &lt_cols, &nnz, &wj, &a, loops);
                    beacon_objective(&f.l, &f.r, &wj, &q)
                });
            let sum: f64 = objs.iter().sum();
            cells.push(format!("{:.5}", sum / nch as f64));
        }
        table.row(cells);
    }
    Ok(table)
}

/// A1: calibration-set size ablation (Beacon w/o EC, 2-bit).
pub fn ablate_calib(pipe: &mut Pipeline, sizes: &[usize]) -> Result<Table> {
    let mut table = Table::new(
        "A1 — calibration size vs top-1 (beacon, 2-bit, w/o EC)",
        &["calib images", "top-1 %"],
    );
    for &n in sizes {
        let qc = QuantConfig {
            method: Method::Beacon,
            bits: 2.0,
            calib_count: n,
            ..QuantConfig::default()
        };
        // calibration subsetting happens inside quantize via acts slicing
        let report = quantize_with_calib_subset(pipe, &qc, n)?;
        table.row(vec![n.to_string(), pct(report)]);
    }
    Ok(table)
}

/// Quantize using only the first `n` calibration images' activations.
/// (The collect_acts artifact is shape-specialized to the full calib set,
/// so subsetting slices token rows out of the captured activations.)
fn quantize_with_calib_subset(pipe: &mut Pipeline, qc: &QuantConfig, n: usize) -> Result<f64> {
    let store = pipe.weights_fp.clone();
    let (_, acts_full) = pipe.collect_acts(&store)?;
    let tokens_per_img = pipe.cfg().tokens();
    let rows = (n * tokens_per_img).min(acts_full[0].rows);
    let quantizable = pipe.artifacts.manifest.quantizable.clone();
    let mut work = store.clone();
    for (li, lname) in quantizable.iter().enumerate() {
        let x_full = &acts_full[li];
        let x = Matrix::from_vec(
            rows,
            x_full.cols,
            x_full.data[..rows * x_full.cols].to_vec(),
        );
        let w = work.matrix(lname);
        let dq = pipe.quantize_layer(qc, &x, &x, &w)?;
        work.set_matrix(lname, &dq);
    }
    super::eval::top1(pipe, &work, qc.eval_count)
}

/// A2: per-layer *deployed* reconstruction error with and without error
/// correction. Both arms quantize sequentially and are scored against the
/// activations the quantized model actually feeds the layer
/// (‖XW − X̃Q‖/‖XW‖, the §3 objective); only the w/ E.C. arm gets to SEE
/// X̃ during quantization. This isolates exactly what EC buys.
pub fn ablate_ec(pipe: &mut Pipeline, bits: BitWidth) -> Result<Table> {
    let mut table = Table::new(
        &format!(
            "A2 — per-layer deployed recon error ‖XW − X̃Q‖/‖XW‖ at {} (beacon)",
            bits.label()
        ),
        &["layer", "w/o E.C.", "w/ E.C.", "EC gain %"],
    );
    let store = pipe.weights_fp.clone();
    let (_, acts_fp) = pipe.collect_acts(&store)?;
    let quantizable = pipe.artifacts.manifest.quantizable.clone();

    let run = |pipe: &Pipeline, use_ec: bool| -> Result<Vec<f64>> {
        let qc = QuantConfig {
            method: Method::Beacon,
            bits: bits.0,
            ..QuantConfig::default()
        };
        let mut work = pipe.weights_fp.clone();
        let mut errs = Vec::with_capacity(quantizable.len());
        for (li, lname) in quantizable.iter().enumerate() {
            let (_, acts_q) = pipe.collect_acts(&work)?;
            let x = &acts_fp[li];
            let xt = &acts_q[li];
            let w = work.matrix(lname);
            let dq = if use_ec {
                pipe.quantize_layer(&qc, x, xt, &w)?
            } else {
                pipe.quantize_layer(&qc, x, x, &w)?
            };
            errs.push(crate::quant::metrics::layer_recon_error_ec(x, xt, &w, &dq));
            work.set_matrix(lname, &dq);
        }
        Ok(errs)
    };

    let plain = run(pipe, false)?;
    let ec = run(pipe, true)?;
    for ((name, e1), e2) in quantizable.iter().zip(&plain).zip(&ec) {
        table.row(vec![
            name.clone(),
            format!("{e1:.4}"),
            format!("{e2:.4}"),
            format!("{:+.1}", 100.0 * (e1 - e2) / e1.max(1e-12)),
        ]);
    }
    Ok(table)
}

/// S1: auto-plan budget sweep — for each effective-bits budget, search a
/// plan ([`Pipeline::auto_plan`]) over `space`'s candidate grid (its
/// `budget_bits` is replaced per row), run it, and report it next to the
/// uniform plan at the budget width (when the budget names a supported
/// width) so the allocation's edge over uniform precision is visible.
pub fn budget_sweep(
    pipe: &mut Pipeline,
    base: &QuantConfig,
    space: &SearchSpace,
    budgets: &[f64],
) -> Result<Table> {
    let mut table = Table::new(
        "S1 — auto-plan budget sweep (searched vs uniform at the budget width)",
        &["budget", "searched eff bits", "searched top-1 %", "uniform top-1 %", "plan"],
    );
    for &budget in budgets {
        let mut space = space.clone();
        space.budget_bits = budget;
        let (plan, preport) = pipe.auto_plan(base, &space)?;
        let report = pipe.quantize(&plan)?;
        let uniform = match BitWidth::parse(&format!("{budget}")) {
            Some(b) => {
                let qc = QuantConfig { bits: b.0, ..base.clone() };
                pct(pipe.quantize_cfg(&qc)?.top1)
            }
            None => "—".to_string(),
        };
        table.row(vec![
            format!("{budget:.2}"),
            format!("{:.3}", preport.effective_bits),
            pct(report.top1),
            uniform,
            plan.label(),
        ]);
    }
    Ok(table)
}

/// Runtime row of Table 1: wall-clock of each Beacon variant relative to
/// GPTQ on the same stack.
pub fn runtime_row(pipe: &mut Pipeline, bits: BitWidth, loops: usize) -> Result<Table> {
    let mut table = Table::new(
        &format!("Table 1 runtime row — relative to GPTQ at {}", bits.label()),
        &["method", "seconds", "× GPTQ"],
    );
    // warm up: FP activation capture, artifact compilation, eval — one-time
    // costs that must not land in the first timed arm
    pipe.fp_top1()?;
    let _ = pipe.quantize_cfg(&QuantConfig {
        method: Method::Rtn,
        bits: bits.0,
        eval_count: 128,
        ..QuantConfig::default()
    })?;
    // ...including the per-shape Beacon kernel compilations (K=0 pass)
    let _ = pipe.quantize_cfg(&QuantConfig {
        method: Method::Beacon,
        bits: bits.0,
        loops: 0,
        eval_count: 128,
        ..QuantConfig::default()
    })?;
    // timed region = the quantization pass itself (report.quantize_secs
    // excludes eval and the cached FP setup), matching how the paper
    // reports algorithm runtime
    let time_of = |pipe: &mut Pipeline, qc: &QuantConfig| -> Result<f64> {
        let report = pipe.quantize_cfg(qc)?;
        Ok(report.quantize_secs + report.ln_tune_secs)
    };
    let gptq_s = time_of(
        pipe,
        &QuantConfig { method: Method::Gptq, bits: bits.0, ..QuantConfig::default() },
    )?;
    let configs: Vec<(&str, QuantConfig)> = vec![
        (
            "beacon w/o EC",
            QuantConfig {
                method: Method::Beacon,
                bits: bits.0,
                loops,
                ..QuantConfig::default()
            },
        ),
        (
            "beacon w/ EC",
            QuantConfig {
                method: Method::Beacon,
                bits: bits.0,
                loops,
                error_correction: true,
                ..QuantConfig::default()
            },
        ),
        (
            "beacon w/ EC+centering",
            QuantConfig {
                method: Method::Beacon,
                bits: bits.0,
                loops,
                error_correction: true,
                centering: true,
                ..QuantConfig::default()
            },
        ),
        (
            "beacon w/ EC+centering+LN",
            QuantConfig {
                method: Method::Beacon,
                bits: bits.0,
                loops,
                error_correction: true,
                centering: true,
                ln_tune: true,
                ..QuantConfig::default()
            },
        ),
    ];
    table.row(vec!["gptq".into(), format!("{gptq_s:.2}"), "1.00".into()]);
    for (name, qc) in configs {
        let s = time_of(pipe, &qc)?;
        table.row(vec![name.into(), format!("{s:.2}"), format!("{:.2}", s / gptq_s)]);
    }
    Ok(table)
}
