//! The L3 coordinator: a layer- and channel-parallel PTQ pipeline
//! (layer-sequential only under error-correction recapture) that drives
//! the whole stack — calibration capture, QR factorization, per-channel
//! quantization through `Box<dyn Quantizer>` (native kernels or the AOT
//! Pallas artifact), error-correction recapture, centering, LayerNorm
//! tuning, and evaluation — entirely from Rust over PJRT artifacts.

pub mod eval;
pub mod experiments;
pub mod lntune;
pub mod pipeline;
pub mod planner;
pub mod report;

pub use pipeline::{KernelBackend, LayerReport, Pipeline, QuantReport};
pub use planner::{LayerProbe, PlannerReport, ProbeCell};
