//! The L3 coordinator: a layer-sequential, channel-parallel PTQ pipeline
//! that drives the whole stack — calibration capture, QR factorization,
//! per-channel Beacon (native or via the AOT Pallas kernel), baselines,
//! error-correction recapture, centering, LayerNorm tuning, and
//! evaluation — entirely from Rust over PJRT artifacts.

pub mod eval;
pub mod experiments;
pub mod lntune;
pub mod pipeline;
pub mod report;

pub use pipeline::{KernelBackend, Pipeline, QuantReport};
