//! The quantization pipeline (the system around Algorithm 1).
//!
//! Data flow per quantizable layer (pipeline order = forward order):
//!
//! ```text
//!   calib images ─► collect_acts(FP weights)     ─► X   (cached once)
//!                 └► collect_acts(work weights)  ─► X̃  (EC recapture)
//!   QR(X̃) ─► L = UᵀX, L̃ = R          (rust/src/linalg — §3 memory form)
//!   channels ─► beacon kernel (PJRT pallas artifact or native twin)
//!   W ← Q·Diag(s) (+ centering row)   (mutates the WeightStore in place)
//! ```
//!
//! after all layers: optional LN tuning (PJRT grad-step artifact), then
//! top-1 evaluation through the `vit_logits` artifact.

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::{Method, QuantConfig, RecapturePolicy};
use crate::data::Dataset;
use crate::linalg::{qr_factor, Matrix};
use crate::model::spec::param_spec;
use crate::model::WeightStore;
use crate::quant::alphabet::alphabet;
use crate::quant::beacon::{beacon_layer_prefactored, BeaconOpts, LayerQuant};
use crate::quant::{comq_layer, gptq_layer, rtn_layer};
use crate::runtime::client::{literal_f32, literal_to_f32};
use crate::runtime::{Artifacts, Runtime};

/// Which implementation executes the Beacon inner sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelBackend {
    /// The AOT-compiled Pallas kernel through PJRT (the paper stack).
    Pjrt,
    /// The native Rust twin (bit-compatible contract; used for perf
    /// comparison and as fallback when an artifact shape is missing).
    Native,
}

#[derive(Debug, Clone)]
pub struct QuantReport {
    pub label: String,
    pub fp_top1: f64,
    pub top1: f64,
    pub layer_errors: Vec<(String, f64)>,
    pub quantize_secs: f64,
    pub ln_tune_secs: f64,
    pub eval_secs: f64,
    pub ln_tune_losses: Vec<f32>,
}

impl QuantReport {
    pub fn accuracy_drop(&self) -> f64 {
        (self.fp_top1 - self.top1) * 100.0
    }
}

pub struct Pipeline {
    pub runtime: Runtime,
    pub artifacts: Artifacts,
    pub weights_fp: WeightStore,
    pub calib: Dataset,
    pub eval: Dataset,
    pub backend: KernelBackend,
    /// cached FP activations (inputs to each quantizable layer) + logits
    acts_fp: Option<Vec<Matrix>>,
    fp_logits_calib: Option<Vec<f32>>,
    fp_top1: Option<f64>,
}

impl Pipeline {
    pub fn from_artifacts(dir: impl AsRef<Path>, config_name: &str) -> Result<Pipeline> {
        let artifacts = Artifacts::load(dir.as_ref(), config_name)?;
        let cfg = artifacts.manifest.cfg.clone();
        let weights_fp = WeightStore::load(&artifacts.manifest.weights, &cfg)?;
        let calib = Dataset::load(&artifacts.manifest.calib)?;
        let eval = Dataset::load(&artifacts.manifest.eval)?;
        let runtime = Runtime::cpu()?;
        Ok(Pipeline {
            runtime,
            artifacts,
            weights_fp,
            calib,
            eval,
            backend: KernelBackend::Pjrt,
            acts_fp: None,
            fp_logits_calib: None,
            fp_top1: None,
        })
    }

    pub fn cfg(&self) -> &crate::model::spec::ViTConfig {
        &self.artifacts.manifest.cfg
    }

    /// Run the collect_acts artifact for the given weights over the whole
    /// calibration set. Returns (logits, per-layer activation matrices).
    pub fn collect_acts(&self, store: &WeightStore) -> Result<(Vec<f32>, Vec<Matrix>)> {
        let m = &self.artifacts.manifest;
        let cfg = &m.cfg;
        anyhow::ensure!(
            self.calib.count == m.calib_count,
            "calib dataset size {} != artifact batch {}",
            self.calib.count,
            m.calib_count
        );
        let mut inputs = Vec::new();
        for t in store.ordered() {
            let dims: Vec<i64> = t.shape.iter().map(|d| *d as i64).collect();
            inputs.push(literal_f32(&t.data, &dims)?);
        }
        inputs.push(literal_f32(
            &self.calib.images,
            &[
                self.calib.count as i64,
                cfg.image as i64,
                cfg.image as i64,
                cfg.channels as i64,
            ],
        )?);
        let out = self.runtime.exec(&m.collect_acts, &inputs)?;
        anyhow::ensure!(
            out.len() == 1 + m.quantizable.len(),
            "collect_acts returned {} outputs, expected {}",
            out.len(),
            1 + m.quantizable.len()
        );
        let logits = literal_to_f32(&out[0])?;
        let tokens = self.calib.count * cfg.tokens();
        let spec: std::collections::BTreeMap<String, Vec<usize>> = param_spec(cfg)
            .into_iter()
            .map(|p| (p.name, p.shape))
            .collect();
        let mut acts = Vec::with_capacity(m.quantizable.len());
        for (i, lname) in m.quantizable.iter().enumerate() {
            let n = spec[lname][0];
            let data = literal_to_f32(&out[1 + i])?;
            anyhow::ensure!(
                data.len() == tokens * n,
                "activation {lname}: {} values, expected {}",
                data.len(),
                tokens * n
            );
            acts.push(Matrix::from_f32(tokens, n, &data));
        }
        Ok((logits, acts))
    }

    fn ensure_fp_acts(&mut self) -> Result<()> {
        if self.acts_fp.is_none() {
            let (logits, acts) = self.collect_acts(&self.weights_fp.clone())?;
            self.acts_fp = Some(acts);
            self.fp_logits_calib = Some(logits);
        }
        Ok(())
    }

    pub fn fp_top1(&mut self) -> Result<f64> {
        if let Some(v) = self.fp_top1 {
            return Ok(v);
        }
        let store = self.weights_fp.clone();
        let v = crate::coordinator::eval::top1(self, &store, 0)?;
        self.fp_top1 = Some(v);
        Ok(v)
    }

    /// Quantize one layer's weights with the configured method.
    /// `x` is the FP activation matrix, `xt` the (possibly identical)
    /// partially-quantized-model activations.
    pub fn quantize_layer(
        &self,
        qc: &QuantConfig,
        x: &Matrix,
        xt: &Matrix,
        w: &Matrix,
    ) -> Result<Matrix> {
        Ok(match qc.method {
            Method::Rtn => rtn_layer(w, qc.bit_width()),
            Method::Gptq => gptq_layer(xt, w, qc.bit_width(), qc.gptq_damp),
            Method::Comq => comq_layer(xt, w, qc.bit_width(), qc.loops),
            Method::Beacon => {
                let lq = self.beacon_layer(qc, x, xt, w)?;
                lq.dequant
            }
        })
    }

    /// Beacon over one layer, dispatching to the PJRT Pallas kernel or the
    /// native twin. Centering (§3) is handled here — the kernel sees the
    /// centered weights either way.
    pub fn beacon_layer(
        &self,
        qc: &QuantConfig,
        x: &Matrix,
        xt: &Matrix,
        w: &Matrix,
    ) -> Result<LayerQuant> {
        let alph = alphabet(qc.bit_width());
        let opts = BeaconOpts { loops: qc.loops, centering: qc.centering };
        let f = qr_factor(xt, x);
        match self.backend {
            KernelBackend::Native => Ok(beacon_layer_prefactored(
                &f.l, &f.r, x, xt, w, &alph, &opts,
            )),
            KernelBackend::Pjrt => {
                self.beacon_layer_pjrt(qc, &f.l, &f.r, x, xt, w, &alph, &opts)
            }
        }
    }

    /// Execute the AOT Pallas kernel artifact for one layer.
    #[allow(clippy::too_many_arguments)]
    fn beacon_layer_pjrt(
        &self,
        _qc: &QuantConfig,
        l: &Matrix,
        r: &Matrix,
        x: &Matrix,
        xt: &Matrix,
        w: &Matrix,
        alph: &[f64],
        opts: &BeaconOpts,
    ) -> Result<LayerQuant> {
        let (n, np) = (w.rows, w.cols);
        let hlo = self.artifacts.beacon_layer_hlo(n, np)?;
        let pad = self.artifacts.manifest.alph_pad;
        if alph.len() > pad {
            bail!("alphabet {} wider than artifact pad {}", alph.len(), pad);
        }

        // center weights if requested (mirror of the native path)
        let z_w: Vec<f64> = (0..np)
            .map(|j| (0..n).map(|i| w[(i, j)]).sum::<f64>() / n as f64)
            .collect();
        let mut w_in = w.clone();
        if opts.centering {
            for i in 0..n {
                for j in 0..np {
                    w_in[(i, j)] -= z_w[j];
                }
            }
        }

        // pad alphabet by repeating the max (inert under first-max argmax)
        let mut alph_pad: Vec<f32> = alph.iter().map(|v| *v as f32).collect();
        while alph_pad.len() < pad {
            alph_pad.push(*alph_pad.last().unwrap());
        }

        let inputs = vec![
            literal_f32(&l.to_f32(), &[n as i64, n as i64])?,
            literal_f32(&r.to_f32(), &[n as i64, n as i64])?,
            literal_f32(&w_in.to_f32(), &[n as i64, np as i64])?,
            crate::runtime::literal_f32_1d(&alph_pad),
            crate::runtime::literal_i32_1d(&[opts.loops as i32]),
        ];
        let out = self.runtime.exec(hlo, &inputs)?;
        anyhow::ensure!(out.len() == 2, "beacon artifact returned {}", out.len());
        let q_flat = literal_to_f32(&out[0])?;
        let scales_f32 = literal_to_f32(&out[1])?;
        anyhow::ensure!(q_flat.len() == n * np && scales_f32.len() == np);

        let codes_m = Matrix::from_f32(n, np, &q_flat);
        let scales: Vec<f64> = scales_f32.iter().map(|v| f64::from(*v)).collect();

        // centering restore: z_Q = (⟨X̃1, X1⟩/‖X̃1‖²)·z_W
        let offsets: Vec<f64> = if opts.centering {
            let ones = vec![1.0f64; n];
            let x1 = x.matvec(&ones);
            let xt1 = xt.matvec(&ones);
            let den = crate::linalg::matrix::dot(&xt1, &xt1);
            let z_scale = if den > 1e-12 {
                crate::linalg::matrix::dot(&x1, &xt1) / den
            } else {
                1.0
            };
            z_w.iter().map(|z| z_scale * z).collect()
        } else {
            vec![0.0; np]
        };

        let mut dequant = Matrix::zeros(n, np);
        let mut codes = Vec::with_capacity(np);
        for j in 0..np {
            let mut col = Vec::with_capacity(n);
            for i in 0..n {
                let q = f64::from(codes_m[(i, j)] as f32);
                dequant[(i, j)] = scales[j] * q + offsets[j];
                col.push(q);
            }
            codes.push(col);
        }
        Ok(LayerQuant { codes, scales, offsets, dequant })
    }

    /// Run the full PTQ pipeline and evaluate. The FP model is left
    /// untouched; the quantized weights are returned inside the report
    /// via `out_store` when provided.
    pub fn quantize(&mut self, qc: &QuantConfig) -> Result<QuantReport> {
        let (report, _) = self.quantize_with_weights(qc)?;
        Ok(report)
    }

    pub fn quantize_with_weights(
        &mut self,
        qc: &QuantConfig,
    ) -> Result<(QuantReport, WeightStore)> {
        self.ensure_fp_acts()?;
        let fp_top1 = self.fp_top1()?;
        let acts_fp = self.acts_fp.clone().expect("ensured");
        let quantizable = self.artifacts.manifest.quantizable.clone();
        let use_ec = qc.method == Method::Beacon && qc.error_correction;

        let t0 = Instant::now();
        let mut work = self.weights_fp.clone();
        let mut layer_errors = Vec::with_capacity(quantizable.len());
        let mut acts_q: Option<Vec<Matrix>> = None;

        for (li, lname) in quantizable.iter().enumerate() {
            let x = &acts_fp[li];
            // error-correction recapture of X̃ from the current weights
            let xt: &Matrix = if use_ec {
                let refresh = match qc.recapture {
                    RecapturePolicy::PerLayer => true,
                    RecapturePolicy::PerBlock => li % 4 == 0,
                };
                if refresh || acts_q.is_none() {
                    let (_, acts) = self
                        .collect_acts(&work)
                        .context("EC recapture")?;
                    acts_q = Some(acts);
                }
                &acts_q.as_ref().unwrap()[li]
            } else {
                x
            };

            let w = work.matrix(lname);
            let dequant = self.quantize_layer(qc, x, xt, &w)?;
            // gram-based metric: avoids two m×N×N' products per layer
            layer_errors.push((
                lname.clone(),
                crate::quant::metrics::layer_recon_error_gram(&x.gram(), &w, &dequant),
            ));
            work.set_matrix(lname, &dequant);
        }
        let quantize_secs = t0.elapsed().as_secs_f64();

        // optional LN tuning (distillation against the FP calib logits)
        let t_ln = Instant::now();
        let ln_tune_losses = if qc.ln_tune {
            let teacher = self.fp_logits_calib.clone().expect("ensured");
            crate::coordinator::lntune::tune(self, &mut work, &teacher, qc)?
        } else {
            Vec::new()
        };
        let ln_tune_secs = t_ln.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let top1 = crate::coordinator::eval::top1(self, &work, qc.eval_count)?;
        let eval_secs = t1.elapsed().as_secs_f64();

        Ok((
            QuantReport {
                label: qc.label(),
                fp_top1,
                top1,
                layer_errors,
                quantize_secs,
                ln_tune_secs,
                eval_secs,
                ln_tune_losses,
            },
            work,
        ))
    }
}
