//! The quantization pipeline (the system around Algorithm 1).
//!
//! Data flow per quantizable layer (pipeline order = forward order):
//!
//! ```text
//!   calib images ─► collect_acts(FP weights)     ─► X   (cached once)
//!                 └► collect_acts(work weights)  ─► X̃  (EC recapture)
//!   QR(X̃) ─► L = UᵀX, L̃ = R          (rust/src/linalg — §3 memory form)
//!   channels ─► quantizer kernel (PJRT pallas artifact or native twin)
//!   W ← Q·Diag(s) (+ centering row)   (mutates the WeightStore in place)
//! ```
//!
//! The pipeline consumes a [`crate::config::QuantPlan`]: one resolved
//! `(method, bits, opts)` assignment per quantizable layer, compiled by
//! [`crate::config::PlanBuilder`] (a flat [`QuantConfig`] rides through
//! the [`Pipeline::quantize_cfg`] shim as a uniform plan). Method
//! dispatch is entirely through `Box<dyn Quantizer>`, picked per layer
//! from the plan entry: this file contains no per-method logic. Without
//! error-correction recapture the layers are independent and the engine
//! scheduler fans them (and each layer's channels) over the
//! `QuantConfig::threads` budget — results are gathered in index order,
//! bit-identical to the serial run.
//!
//! after all layers: optional LN tuning (PJRT grad-step artifact), then
//! top-1 evaluation through the `vit_logits` artifact.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::{Method, QuantConfig, QuantPlan, RecapturePolicy, SearchSpace};
use crate::data::Dataset;
use crate::linalg::{qr_factor, Matrix};
use crate::model::spec::param_spec;
use crate::model::{PackedLayer, PackedStore, WeightStore};
use crate::quant::alphabet::{alphabet, BitWidth};
use crate::quant::beacon::BeaconOpts;
use crate::quant::engine::{self, LayerCtx, LayerQuant, Quantizer};
use crate::runtime::client::{literal_f32, literal_to_f32};
use crate::runtime::{Artifacts, Runtime};

/// Which implementation executes the Beacon inner sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelBackend {
    /// The AOT-compiled Pallas kernel through PJRT (the paper stack).
    Pjrt,
    /// The native Rust twin (bit-compatible contract; used for perf
    /// comparison and as fallback when an artifact shape is missing).
    Native,
}

/// One row of a [`QuantReport`]: what the plan assigned to a layer and
/// the relative reconstruction error the assignment achieved.
#[derive(Debug, Clone)]
pub struct LayerReport {
    pub layer: String,
    pub method: Method,
    pub bits: BitWidth,
    pub error: f64,
}

#[derive(Debug, Clone)]
pub struct QuantReport {
    pub label: String,
    pub fp_top1: f64,
    pub top1: f64,
    /// per-layer `(method, bits, error)` rows, in pipeline order
    pub layers: Vec<LayerReport>,
    /// nominal bits per weight across the plan, weighted by layer size
    pub effective_bits: f64,
    pub quantize_secs: f64,
    pub ln_tune_secs: f64,
    pub eval_secs: f64,
    pub ln_tune_losses: Vec<f32>,
    /// how the plan was searched, when it came from `--auto-plan`
    /// ([`Pipeline::auto_plan`]); `None` for hand-written plans
    pub planner: Option<super::planner::PlannerReport>,
    /// recorder-derived run metrics (worker utilization, cache hit
    /// rate, per-channel latency); `None` unless tracing was enabled
    pub metrics: Option<crate::obs::MetricsReport>,
    /// heap accounting (per-phase deltas, resident footprints, packed
    /// ratio); `None` unless tracing was enabled
    pub memory: Option<crate::obs::MemoryReport>,
}

impl QuantReport {
    pub fn accuracy_drop(&self) -> f64 {
        (self.fp_top1 - self.top1) * 100.0
    }

    /// The legacy `(layer name, error)` view of the per-layer rows.
    pub fn layer_errors(&self) -> impl Iterator<Item = (&str, f64)> + '_ {
        self.layers.iter().map(|r| (r.layer.as_str(), r.error))
    }
}

/// Accumulates the packed-weights footprint across layers for the
/// [`MemoryReport`](crate::obs::MemoryReport) packed-vs-f32 ratio —
/// the paper's storage-model claim, checked on the actual codes. Any
/// off-grid channel (e.g. an experimental method emitting raw values)
/// voids the whole measurement rather than reporting a partial ratio.
#[derive(Default)]
struct PackedAccum {
    payload: u64,
    meta: u64,
    fp: u64,
    weighted_bits: u64,
    failed: bool,
}

impl PackedAccum {
    fn add_layer(&mut self, lq: &LayerQuant, bits: BitWidth) {
        if self.failed {
            return;
        }
        match crate::quant::packing::layer_packed_bytes(&lq.codes, bits) {
            Some((payload, meta)) => {
                let numel: u64 = lq.codes.iter().map(|c| c.len() as u64).sum();
                self.payload += payload;
                self.meta += meta;
                self.fp += numel * 4;
                self.weighted_bits += numel * u64::from(bits.storage_bits());
            }
            None => self.failed = true,
        }
    }

    fn finish(self) -> Option<crate::obs::memory::PackedFootprint> {
        if self.failed || self.fp == 0 {
            return None;
        }
        Some(crate::obs::memory::PackedFootprint {
            payload_bytes: self.payload,
            meta_bytes: self.meta,
            fp_bytes: self.fp,
            theoretical_ratio: self.weighted_bits as f64 / (self.fp as f64 * 8.0),
        })
    }
}

pub struct Pipeline {
    pub runtime: Runtime,
    pub artifacts: Artifacts,
    pub weights_fp: WeightStore,
    pub calib: Dataset,
    pub eval: Dataset,
    pub backend: KernelBackend,
    /// cached FP activations (inputs to each quantizable layer) + logits
    acts_fp: Option<Vec<Matrix>>,
    /// cached per-layer grams XᵀX of `acts_fp` — computed once and shared
    /// by per-layer error reporting and the planner probes
    grams_fp: Option<Vec<Matrix>>,
    fp_logits_calib: Option<Vec<f32>>,
    fp_top1: Option<f64>,
}

impl Pipeline {
    pub fn from_artifacts(dir: impl AsRef<Path>, config_name: &str) -> Result<Pipeline> {
        let artifacts = Artifacts::load(dir.as_ref(), config_name)?;
        let cfg = artifacts.manifest.cfg.clone();
        let weights_fp = WeightStore::load(&artifacts.manifest.weights, &cfg)?;
        let calib = Dataset::load(&artifacts.manifest.calib)?;
        let eval = Dataset::load(&artifacts.manifest.eval)?;
        crate::obs::memory::set_resident(
            "model.weights_fp",
            weights_fp.resident_bytes(),
        );
        crate::obs::memory::set_resident("data.calib", calib.resident_bytes());
        crate::obs::memory::set_resident("data.eval", eval.resident_bytes());
        let runtime = Runtime::cpu()?;
        Ok(Pipeline {
            runtime,
            artifacts,
            weights_fp,
            calib,
            eval,
            backend: KernelBackend::Pjrt,
            acts_fp: None,
            grams_fp: None,
            fp_logits_calib: None,
            fp_top1: None,
        })
    }

    pub fn cfg(&self) -> &crate::model::spec::ViTConfig {
        &self.artifacts.manifest.cfg
    }

    /// Run the collect_acts artifact for the given weights over the whole
    /// calibration set. Returns (logits, per-layer activation matrices).
    pub fn collect_acts(&self, store: &WeightStore) -> Result<(Vec<f32>, Vec<Matrix>)> {
        let m = &self.artifacts.manifest;
        let cfg = &m.cfg;
        anyhow::ensure!(
            self.calib.count == m.calib_count,
            "calib dataset size {} != artifact batch {}",
            self.calib.count,
            m.calib_count
        );
        let mut inputs = Vec::new();
        for t in store.ordered() {
            let dims: Vec<i64> = t.shape.iter().map(|d| *d as i64).collect();
            inputs.push(literal_f32(&t.data, &dims)?);
        }
        inputs.push(literal_f32(
            &self.calib.images,
            &[
                self.calib.count as i64,
                cfg.image as i64,
                cfg.image as i64,
                cfg.channels as i64,
            ],
        )?);
        let out = self.runtime.exec(&m.collect_acts, &inputs)?;
        anyhow::ensure!(
            out.len() == 1 + m.quantizable.len(),
            "collect_acts returned {} outputs, expected {}",
            out.len(),
            1 + m.quantizable.len()
        );
        let logits = literal_to_f32(&out[0])?;
        let tokens = self.calib.count * cfg.tokens();
        let spec: std::collections::BTreeMap<String, Vec<usize>> = param_spec(cfg)
            .into_iter()
            .map(|p| (p.name, p.shape))
            .collect();
        let mut acts = Vec::with_capacity(m.quantizable.len());
        for (i, lname) in m.quantizable.iter().enumerate() {
            let n = spec[lname][0];
            let data = literal_to_f32(&out[1 + i])?;
            anyhow::ensure!(
                data.len() == tokens * n,
                "activation {lname}: {} values, expected {}",
                data.len(),
                tokens * n
            );
            acts.push(Matrix::from_f32(tokens, n, &data));
        }
        Ok((logits, acts))
    }

    fn ensure_fp_acts(&mut self) -> Result<()> {
        if self.acts_fp.is_none() {
            let (logits, acts) = self.collect_acts(&self.weights_fp.clone())?;
            self.acts_fp = Some(acts);
            self.fp_logits_calib = Some(logits);
        }
        Ok(())
    }

    /// Each layer's gram XᵀX over the cached FP activations, computed
    /// exactly once per pipeline (the layers fan over the pool — grams
    /// are pure, so the cache is bit-identical at any thread count).
    /// Shared by quantization error reporting and the planner probes,
    /// which used to compute the same matrices independently.
    fn ensure_fp_grams(&mut self) -> Result<()> {
        self.ensure_fp_acts()?;
        if let Some(g) = &self.grams_fp {
            crate::obs::counter("pipeline.gram_cache.hit", g.len() as u64);
        } else {
            let _span = crate::obs::span("phase", "phase.gram_build");
            let acts = self.acts_fp.as_ref().expect("ensured");
            crate::obs::counter("pipeline.gram_cache.miss", acts.len() as u64);
            let threads = crate::util::pool::resolve_threads(0);
            let grams = crate::util::pool::par_map_labeled(
                "pipeline.grams",
                acts.len(),
                threads,
                |i| acts[i].gram(),
            );
            let bytes: u64 =
                grams.iter().map(|g| (g.data.len() * 8) as u64).sum();
            crate::obs::memory::set_resident("pipeline.gram_cache", bytes);
            self.grams_fp = Some(grams);
        }
        Ok(())
    }

    pub fn fp_top1(&mut self) -> Result<f64> {
        if let Some(v) = self.fp_top1 {
            return Ok(v);
        }
        let store = self.weights_fp.clone();
        let v = crate::coordinator::eval::top1(self, &store, 0)?;
        self.fp_top1 = Some(v);
        Ok(v)
    }

    /// The model's quantizable layer names, in pipeline order — what
    /// plans are compiled against ([`crate::config::PlanBuilder::build`]).
    pub fn quantizable(&self) -> &[String] {
        &self.artifacts.manifest.quantizable
    }

    /// Compile a uniform [`QuantPlan`] (every layer gets `qc`'s
    /// method/bits) against this pipeline's model.
    pub fn uniform_plan(&self, qc: &QuantConfig) -> Result<QuantPlan> {
        QuantPlan::uniform(qc, self.quantizable())
    }

    /// Search a [`QuantPlan`] automatically (`--auto-plan`): probe every
    /// candidate `(method, bits)` in `space` on every quantizable layer
    /// against the calibration grams (computed once and shared with the
    /// quantization error reporting), then greedily allocate widths under
    /// `space.budget_bits`. See [`super::planner`] for the algorithm and
    /// its determinism/monotonicity guarantees. The emitted plan
    /// round-trips through [`QuantPlan::to_manifest`], so `--save-plan`
    /// makes the search reproducible and diffable.
    pub fn auto_plan(
        &mut self,
        base: &QuantConfig,
        space: &SearchSpace,
    ) -> Result<(QuantPlan, super::planner::PlannerReport)> {
        self.ensure_fp_grams()?;
        let quantizable = self.artifacts.manifest.quantizable.clone();
        let acts = self.acts_fp.as_ref().expect("ensured");
        let grams = self.grams_fp.as_ref().expect("ensured");
        let weights: Vec<Matrix> =
            quantizable.iter().map(|l| self.weights_fp.matrix(l)).collect();
        let probes: Vec<super::planner::LayerProbe<'_>> = quantizable
            .iter()
            .enumerate()
            .map(|(i, l)| super::planner::LayerProbe {
                name: l.as_str(),
                x: &acts[i],
                gram: &grams[i],
                w: &weights[i],
                numel: self.weights_fp.get(l).numel(),
            })
            .collect();
        super::planner::search_plan(base, &probes, space)
    }

    /// The quantizer for one resolved `(method, bits, opts)` assignment:
    /// the method's native implementation, swapped for the PJRT kernel
    /// adapter when the backend is [`KernelBackend::Pjrt`] and the method
    /// runs on the prefactored form the AOT Pallas artifact implements.
    fn quantizer_for<'a>(
        &'a self,
        method: Method,
        bits: BitWidth,
        qc: &QuantConfig,
    ) -> Box<dyn Quantizer + 'a> {
        let native = method.quantizer(bits, qc);
        // The only AOT kernel artifact the bundle ships is the Beacon
        // sweep, so the adapter swap is gated on the method's identity,
        // not just the prefactored capability — a future second
        // prefactored-capable method must bring its own artifact +
        // adapter rather than silently inheriting Beacon's. Grouped /
        // asymmetric / outlier scenarios stay on the native path too:
        // the artifact implements only the dense whole-channel sweep.
        if self.backend == KernelBackend::Pjrt
            && native.supports_prefactored()
            && native.name() == "beacon"
            && crate::quant::Scenario::from_config(qc).is_default()
        {
            return Box::new(PjrtKernelQuantizer {
                pipe: self,
                bits,
                opts: BeaconOpts {
                    loops: qc.loops,
                    centering: qc.centering,
                    threads: 0,
                },
                error_correction: qc.error_correction,
            });
        }
        native
    }

    /// The quantizer for a flat config (validates `qc.bits`).
    pub fn quantizer<'a>(&'a self, qc: &QuantConfig) -> Result<Box<dyn Quantizer + 'a>> {
        Ok(self.quantizer_for(qc.method, qc.bit_width()?, qc))
    }

    /// Quantize one layer's weights with the configured method.
    /// `x` is the FP activation matrix, `xt` the (possibly identical)
    /// partially-quantized-model activations.
    pub fn quantize_layer(
        &self,
        qc: &QuantConfig,
        x: &Matrix,
        xt: &Matrix,
        w: &Matrix,
    ) -> Result<Matrix> {
        let threads = crate::util::pool::resolve_threads(qc.threads);
        let lq = self
            .quantizer(qc)?
            .quantize_layer(&LayerCtx { x, xt, w, threads })?;
        Ok(lq.dequant)
    }

    /// Beacon over one layer, dispatching to the PJRT Pallas kernel or the
    /// native twin (regardless of `qc.method` — this is the
    /// beacon-specific entry point the kernel-parity tests drive).
    pub fn beacon_layer(
        &self,
        qc: &QuantConfig,
        x: &Matrix,
        xt: &Matrix,
        w: &Matrix,
    ) -> Result<LayerQuant> {
        let mut qc_beacon = qc.clone();
        qc_beacon.method = Method::Beacon;
        let threads = crate::util::pool::resolve_threads(qc.threads);
        self.quantizer(&qc_beacon)?
            .quantize_layer(&LayerCtx { x, xt, w, threads })
    }

    /// Execute the AOT Pallas kernel artifact for one layer.
    #[allow(clippy::too_many_arguments)]
    fn beacon_layer_pjrt(
        &self,
        l: &Matrix,
        r: &Matrix,
        x: &Matrix,
        xt: &Matrix,
        w: &Matrix,
        alph: &[f64],
        opts: &BeaconOpts,
    ) -> Result<LayerQuant> {
        let (n, np) = (w.rows, w.cols);
        let hlo = self.artifacts.beacon_layer_hlo(n, np)?;
        let pad = self.artifacts.manifest.alph_pad;
        if alph.len() > pad {
            bail!("alphabet {} wider than artifact pad {}", alph.len(), pad);
        }

        // center weights if requested (mirror of the native path)
        let z_w: Vec<f64> = (0..np)
            .map(|j| (0..n).map(|i| w[(i, j)]).sum::<f64>() / n as f64)
            .collect();
        let mut w_in = w.clone();
        if opts.centering {
            for i in 0..n {
                for j in 0..np {
                    w_in[(i, j)] -= z_w[j];
                }
            }
        }

        // pad alphabet by repeating the max (inert under first-max argmax)
        let mut alph_pad: Vec<f32> = alph.iter().map(|v| *v as f32).collect();
        while alph_pad.len() < pad {
            alph_pad.push(*alph_pad.last().unwrap());
        }

        let inputs = vec![
            literal_f32(&l.to_f32(), &[n as i64, n as i64])?,
            literal_f32(&r.to_f32(), &[n as i64, n as i64])?,
            literal_f32(&w_in.to_f32(), &[n as i64, np as i64])?,
            crate::runtime::literal_f32_1d(&alph_pad),
            crate::runtime::literal_i32_1d(&[opts.loops as i32]),
        ];
        let out = self.runtime.exec(hlo, &inputs)?;
        anyhow::ensure!(out.len() == 2, "beacon artifact returned {}", out.len());
        let q_flat = literal_to_f32(&out[0])?;
        let scales_f32 = literal_to_f32(&out[1])?;
        anyhow::ensure!(q_flat.len() == n * np && scales_f32.len() == np);

        let codes_m = Matrix::from_f32(n, np, &q_flat);
        let scales: Vec<f64> = scales_f32.iter().map(|v| f64::from(*v)).collect();

        // centering restore: z_Q = (⟨X̃1, X1⟩/‖X̃1‖²)·z_W
        let offsets: Vec<f64> = if opts.centering {
            let ones = vec![1.0f64; n];
            let x1 = x.matvec(&ones);
            let xt1 = xt.matvec(&ones);
            let den = crate::linalg::matrix::dot(&xt1, &xt1);
            let z_scale = if den > 1e-12 {
                crate::linalg::matrix::dot(&x1, &xt1) / den
            } else {
                1.0
            };
            z_w.iter().map(|z| z_scale * z).collect()
        } else {
            vec![0.0; np]
        };

        let mut dequant = Matrix::zeros(n, np);
        let mut codes = Vec::with_capacity(np);
        for j in 0..np {
            let mut col = Vec::with_capacity(n);
            for i in 0..n {
                let q = f64::from(codes_m[(i, j)] as f32);
                dequant[(i, j)] = scales[j] * q + offsets[j];
                col.push(q);
            }
            codes.push(col);
        }
        Ok(LayerQuant { codes, scales, offsets, dequant, grouped: None })
    }

    /// Run the full PTQ pipeline under `plan` — each layer quantized by
    /// its own `(method, bits, opts)` assignment — and evaluate. The FP
    /// model is left untouched; use
    /// [`Pipeline::quantize_with_weights`] to also get the quantized
    /// weights.
    pub fn quantize(&mut self, plan: &QuantPlan) -> Result<QuantReport> {
        let (report, _) = self.quantize_with_weights(plan)?;
        Ok(report)
    }

    /// Legacy flat-config entry point: compiles `qc` into a uniform plan
    /// (same method/bits on every layer) and runs it. Bit-identical to
    /// the pre-plan pipeline at any thread count.
    pub fn quantize_cfg(&mut self, qc: &QuantConfig) -> Result<QuantReport> {
        let plan = self.uniform_plan(qc)?;
        self.quantize(&plan)
    }

    /// [`Pipeline::quantize_cfg`] returning the quantized weights too.
    pub fn quantize_cfg_with_weights(
        &mut self,
        qc: &QuantConfig,
    ) -> Result<(QuantReport, WeightStore)> {
        let plan = self.uniform_plan(qc)?;
        self.quantize_with_weights(&plan)
    }

    pub fn quantize_with_weights(
        &mut self,
        plan: &QuantPlan,
    ) -> Result<(QuantReport, WeightStore)> {
        let (report, work, _) = self.quantize_full(plan, false)?;
        Ok((report, work))
    }

    /// [`Pipeline::quantize_with_weights`] that additionally captures the
    /// per-layer codes as a [`PackedStore`] — the deployable low-bit
    /// checkpoint (`--save-packed`). `None` when any layer's codes fall
    /// off the storage grid (an experimental method emitting raw values):
    /// packing degrades gracefully rather than shipping a partial store.
    pub fn quantize_packed(
        &mut self,
        plan: &QuantPlan,
    ) -> Result<(QuantReport, WeightStore, Option<PackedStore>)> {
        self.quantize_full(plan, true)
    }

    fn quantize_full(
        &mut self,
        plan: &QuantPlan,
        want_packed: bool,
    ) -> Result<(QuantReport, WeightStore, Option<PackedStore>)> {
        let quantizable = self.artifacts.manifest.quantizable.clone();
        anyhow::ensure!(
            plan.assignments.len() == quantizable.len(),
            "plan covers {} layers but this model has {} — compile it with \
             PlanBuilder::build(pipe.quantizable())",
            plan.assignments.len(),
            quantizable.len()
        );
        if let Some((a, l)) = plan
            .assignments
            .iter()
            .zip(&quantizable)
            .find(|(a, l)| &a.layer != *l)
        {
            bail!(
                "plan was compiled for a different model: plan layer '{}' vs \
                 this model's '{}' — rebuild with PlanBuilder::build(pipe.quantizable())",
                a.layer,
                l
            );
        }
        self.ensure_fp_grams()?;
        let fp_top1 = self.fp_top1()?;
        let acts_fp = self.acts_fp.clone().expect("ensured");
        let grams_fp = self.grams_fp.clone().expect("ensured");
        let base = &plan.base;

        // one quantizer per layer, picked from the plan entry (uniform
        // plans build identical instances — same numbers as one shared)
        let quantizers: Vec<Box<dyn Quantizer + '_>> = plan
            .assignments
            .iter()
            .map(|a| self.quantizer_for(a.method, a.bits, &a.to_config(base)))
            .collect();
        let any_recapture = quantizers.iter().any(|q| q.uses_recapture());
        let threads = crate::util::pool::resolve_threads(base.threads);
        // EC couples consecutive layers (X̃ depends on the layers already
        // quantized) and the PJRT adapter must stay on this thread; both
        // force the layer axis serial — the whole budget then goes to the
        // channel sweep inside each layer.
        let layer_parallel =
            !any_recapture && quantizers.iter().all(|q| q.parallel_safe());
        let sched = engine::plan(threads, quantizable.len(), layer_parallel);

        let quantize_span = crate::obs::span("phase", "phase.quantize");
        let mut work = self.weights_fp.clone();
        let mut layer_errors = Vec::with_capacity(quantizable.len());
        // packed-footprint accounting is traced-runs-only: it walks
        // every code, so the untraced hot path skips it entirely
        let mut packed_acc = crate::obs::enabled().then(PackedAccum::default);
        // deployable packed checkpoint: one PackedLayer per quantized
        // layer; any off-grid channel voids the whole store
        let mut packed_layers: Option<Vec<PackedLayer>> =
            want_packed.then(Vec::new);
        fn pack_into(
            packed: &mut Option<Vec<PackedLayer>>,
            lname: &str,
            lq: &LayerQuant,
            bits: BitWidth,
        ) {
            if let Some(layers) = packed {
                // scenario-aware: grouped/outlier metadata rides into
                // the store (BPK2); dense layers pack exactly as before
                match PackedLayer::pack_quant(lname, lq, bits) {
                    Some(l) => layers.push(l),
                    None => *packed = None,
                }
            }
        }

        if sched.layer_threads > 1 {
            // independent layers: every layer quantizes the FP weights
            // against the cached FP activations — fan them, gather in
            // index order (bit-identical to the serial path), then apply.
            let results = engine::run_layers(sched, quantizable.len(), |li| {
                let lname = &quantizable[li];
                let x = &acts_fp[li];
                let w = work.matrix(lname);
                let lq = quantizers[li].quantize_layer(&LayerCtx {
                    x,
                    xt: x,
                    w: &w,
                    threads: sched.channel_threads,
                })?;
                // gram-based metric over the shared per-layer gram cache
                let err = crate::quant::metrics::layer_recon_error_gram(
                    &grams_fp[li],
                    &w,
                    &lq.dequant,
                );
                Ok((err, lq))
            })?;
            for (li, (lname, (err, lq))) in
                quantizable.iter().zip(results).enumerate()
            {
                layer_errors.push(err);
                if let Some(acc) = packed_acc.as_mut() {
                    acc.add_layer(&lq, plan.assignments[li].bits);
                }
                pack_into(
                    &mut packed_layers,
                    lname,
                    &lq,
                    plan.assignments[li].bits,
                );
                work.set_matrix(lname, &lq.dequant);
            }
        } else {
            let mut acts_q: Option<Vec<Matrix>> = None;
            for (li, lname) in quantizable.iter().enumerate() {
                let x = &acts_fp[li];
                // error-correction recapture of X̃ from the current
                // weights, for the layers whose assignment asks for it
                let xt: &Matrix = if quantizers[li].uses_recapture() {
                    let refresh = match base.recapture {
                        RecapturePolicy::PerLayer => true,
                        RecapturePolicy::PerBlock => li % 4 == 0,
                    };
                    if refresh || acts_q.is_none() {
                        let (_, acts) =
                            self.collect_acts(&work).context("EC recapture")?;
                        acts_q = Some(acts);
                    }
                    &acts_q.as_ref().unwrap()[li]
                } else {
                    x
                };

                let w = work.matrix(lname);
                let lq = quantizers[li].quantize_layer(&LayerCtx {
                    x,
                    xt,
                    w: &w,
                    threads: sched.channel_threads,
                })?;
                layer_errors.push(crate::quant::metrics::layer_recon_error_gram(
                    &grams_fp[li],
                    &w,
                    &lq.dequant,
                ));
                if let Some(acc) = packed_acc.as_mut() {
                    acc.add_layer(&lq, plan.assignments[li].bits);
                }
                pack_into(
                    &mut packed_layers,
                    lname,
                    &lq,
                    plan.assignments[li].bits,
                );
                work.set_matrix(lname, &lq.dequant);
            }
        }
        drop(quantizers);
        let packed_store = packed_layers.map(|layers| PackedStore { layers });
        if let Some(ps) = &packed_store {
            crate::obs::memory::set_resident(
                "quant.packed_store",
                ps.resident_bytes(),
            );
        }
        let packed = packed_acc.and_then(PackedAccum::finish);
        if let Some(pf) = &packed {
            crate::obs::memory::set_resident(
                "quant.packed_channels",
                pf.payload_bytes + pf.meta_bytes,
            );
        }
        let quantize_secs = quantize_span.finish();

        let layers: Vec<LayerReport> = plan
            .assignments
            .iter()
            .zip(&layer_errors)
            .map(|(a, e)| LayerReport {
                layer: a.layer.clone(),
                method: a.method,
                bits: a.bits,
                error: *e,
            })
            .collect();
        let effective_bits =
            plan.effective_bits(|name| self.weights_fp.get(name).numel());

        // optional LN tuning (distillation against the FP calib logits)
        let ln_span = crate::obs::span("phase", "phase.ln_tune");
        let ln_tune_losses = if base.ln_tune {
            let teacher = self.fp_logits_calib.clone().expect("ensured");
            crate::coordinator::lntune::tune(self, &mut work, &teacher, base)?
        } else {
            Vec::new()
        };
        let ln_tune_secs = ln_span.finish();

        let eval_span = crate::obs::span("phase", "phase.eval");
        let top1 = crate::coordinator::eval::top1(self, &work, base.eval_count)?;
        let eval_secs = eval_span.finish();

        // one snapshot feeds both report sections (metrics + memory),
        // so their event views can never disagree
        let (metrics, memory) = if crate::obs::enabled() {
            let snap = crate::obs::snapshot();
            (
                Some(crate::obs::MetricsReport::from_snapshot(
                    &snap,
                    vec![
                        ("quantize".to_string(), quantize_secs),
                        ("ln_tune".to_string(), ln_tune_secs),
                        ("eval".to_string(), eval_secs),
                    ],
                )),
                Some(crate::obs::MemoryReport::from_snapshot(&snap, packed)),
            )
        } else {
            (None, None)
        };

        Ok((
            QuantReport {
                label: plan.label(),
                fp_top1,
                top1,
                layers,
                effective_bits,
                quantize_secs,
                ln_tune_secs,
                eval_secs,
                ln_tune_losses,
                planner: None,
                metrics,
                memory,
            },
            work,
            packed_store,
        ))
    }
}

/// [`Quantizer`] adapter running the Beacon inner sweep through the
/// AOT-compiled Pallas kernel artifact over PJRT. Selected per layer by
/// the pipeline's quantizer construction whenever the backend is PJRT
/// and the assignment's method consumes the prefactored (L, L̃) form the
/// artifact implements; centering is applied around the kernel call
/// exactly as in the native twin. The bit width is the plan entry's —
/// the artifact takes the (padded) alphabet as an input, so one compiled
/// kernel shape serves every width.
struct PjrtKernelQuantizer<'a> {
    pipe: &'a Pipeline,
    bits: BitWidth,
    opts: BeaconOpts,
    error_correction: bool,
}

impl Quantizer for PjrtKernelQuantizer<'_> {
    fn name(&self) -> &'static str {
        "beacon"
    }

    fn supports_prefactored(&self) -> bool {
        true
    }

    /// PJRT executions are serialized behind the runtime's executable
    /// lock, so fanning layers would only contend — keep the layer axis
    /// on the coordinator thread.
    fn parallel_safe(&self) -> bool {
        false
    }

    fn uses_recapture(&self) -> bool {
        self.error_correction
    }

    fn quantize_layer(&self, ctx: &LayerCtx) -> Result<LayerQuant> {
        let alph = alphabet(self.bits);
        let opts = BeaconOpts { threads: ctx.threads, ..self.opts.clone() };
        let f = qr_factor(ctx.xt, ctx.x);
        self.pipe
            .beacon_layer_pjrt(&f.l, &f.r, ctx.x, ctx.xt, ctx.w, &alph, &opts)
    }
}
