//! Loss-aware automatic plan search: the *policy* layer on top of the
//! PR 1/PR 2 mechanism (`Quantizer` trait + `QuantPlan`).
//!
//! The plan API can express any mixed-method / mixed-precision
//! assignment, but until now every plan was hand-written via `--override`
//! globs. This module *generates* one: LeanQuant/COMQ-style cheap
//! per-layer loss probes drive a greedy budgeted bit allocation, no
//! backprop involved.
//!
//! ```text
//!   per layer: gram G = XᵀX  (computed ONCE, shared with error reporting)
//!     probe every candidate (method, bits):  quantize → err via G
//!   greedy: start all layers at the floor width, repeatedly upgrade the
//!     layer with the best Δerror per Δeffective-bit until the
//!     size-weighted effective_bits budget is exhausted
//!   emit: QuantPlan (+ manifest via --save-plan) + PlannerReport
//! ```
//!
//! Two properties are load-bearing and guaranteed by construction:
//!
//! * **Determinism** — probes fan over [`crate::quant::engine::plan`] /
//!   [`run_probe_grid`](crate::quant::engine::run_probe_grid) (index-order
//!   gather, pure native quantizers), and every tie-break is positional,
//!   so the searched plan is bit-identical at any thread count.
//! * **Budget monotonicity** — the upgrade sequence is simulated once
//!   with an *unbounded* budget (so it depends only on the probe errors
//!   and layer sizes), then applied as a prefix that stops at the first
//!   unaffordable upgrade. A larger budget can only extend the prefix,
//!   so per-layer widths never decrease as the budget grows, and a
//!   budget at the floor (resp. top) candidate width degenerates to the
//!   uniform floor (resp. top) plan.

use anyhow::{anyhow, bail, ensure, Result};

use crate::config::{LayerAssignment, Method, QuantConfig, QuantPlan, SearchSpace};
use crate::linalg::Matrix;
use crate::quant::alphabet::BitWidth;
use crate::quant::engine::{self, LayerCtx};
use crate::quant::metrics::layer_recon_error_gram;
use crate::util::pool;

/// Everything the planner looks at for one layer. The gram is the
/// layer's `XᵀX`, computed once by the caller (the pipeline caches it and
/// shares the same matrix with per-layer error reporting).
#[derive(Clone, Copy)]
pub struct LayerProbe<'a> {
    pub name: &'a str,
    /// FP activations feeding the layer (m×N)
    pub x: &'a Matrix,
    /// gram of `x` (N×N) — the probe scoring fast path
    pub gram: &'a Matrix,
    /// layer weights (N×N'), channels = columns
    pub w: &'a Matrix,
    /// element count (the effective-bits weight)
    pub numel: usize,
}

/// One probed `(method, bits, group_size, outlier_k)` candidate and the
/// relative reconstruction error it achieved on its layer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProbeCell {
    pub method: Method,
    pub bits: BitWidth,
    /// rows per quantization group (0 = per-channel)
    pub group_size: usize,
    /// exact-kept outliers per channel (0 = none)
    pub outlier_k: usize,
    pub error: f64,
}

/// The pure allocation result over a probe error matrix.
#[derive(Debug, Clone)]
pub struct Allocation {
    /// per layer: index into the ascending candidate width ladder
    pub width_idx: Vec<usize>,
    /// per layer: the winning probe cell at the allocated width
    pub chosen: Vec<ProbeCell>,
    /// size-weighted effective bits/weight of the chosen allocation
    pub effective_bits: f64,
    /// the floor (smallest) candidate width every layer starts at
    pub floor_bits: f64,
    pub upgrades_applied: usize,
    pub upgrades_total: usize,
}

/// Per-layer slice of the planner report: the full probe row plus the
/// chosen assignment.
#[derive(Debug, Clone)]
pub struct LayerProbeReport {
    pub layer: String,
    pub numel: usize,
    /// every probed candidate, in (width-major, method-minor) order
    pub probes: Vec<ProbeCell>,
    pub chosen: ProbeCell,
}

/// What the search did: probe counts, the probe error matrix, the chosen
/// allocation and how much of the budget it used. Attached to
/// [`crate::coordinator::QuantReport::planner`] for `--auto-plan` runs
/// and rendered by [`crate::coordinator::report::planner_table`].
#[derive(Debug, Clone)]
pub struct PlannerReport {
    pub budget_bits: f64,
    pub probe_count: usize,
    pub layers: Vec<LayerProbeReport>,
    pub effective_bits: f64,
    pub floor_bits: f64,
    pub upgrades_applied: usize,
    pub upgrades_total: usize,
}

impl PlannerReport {
    /// Fraction of the effective-bits budget the chosen plan uses.
    pub fn budget_utilization(&self) -> f64 {
        self.effective_bits / self.budget_bits
    }
}

/// Probe every `(method, bits)` candidate on every layer and score it
/// with the shared-gram reconstruction error. Rows come back in layer
/// order, cells in (width-major, method-minor) candidate order.
///
/// The sweep reuses the engine scheduler ([`engine::plan`] +
/// [`engine::run_probe_grid`]): layers fan across the layer axis, each
/// probe's channel sweep gets the per-layer channel budget, and gathering
/// is index-ordered — the probe matrix is bit-identical at any thread
/// count. Probes always run the *native* quantizer (pure and
/// parallel-safe; the PJRT adapter is serialized behind a runtime lock)
/// against the FP activations — no error-correction recapture during
/// search.
pub fn probe_errors(
    base: &QuantConfig,
    probes: &[LayerProbe<'_>],
    space: &SearchSpace,
) -> Result<Vec<Vec<ProbeCell>>> {
    space.validate()?;
    if probes.is_empty() {
        bail!("planner needs at least one layer probe");
    }
    let methods = space.resolved_methods(base);
    let widths = space.sorted_widths();
    let group_sizes = space.resolved_group_sizes(base);
    let outlier_ks = space.resolved_outlier_ks(base);
    // width-major candidate grid (allocate builds its ladder from the
    // width of each cell); gptq supports only the dense scenario, so
    // its grouped/outlier combinations are dropped rather than probed
    let mut cands: Vec<(Method, BitWidth, usize, usize)> = Vec::new();
    for b in &widths {
        for m in &methods {
            for g in &group_sizes {
                for k in &outlier_ks {
                    if *m == Method::Gptq && (*g > 0 || *k > 0) {
                        continue;
                    }
                    cands.push((*m, *b, *g, *k));
                }
            }
        }
    }
    ensure!(
        !cands.is_empty(),
        "planner candidate grid is empty after dropping gptq \
         grouped/outlier combinations"
    );
    let threads = pool::resolve_threads(base.threads);
    let sched = engine::plan(threads, probes.len(), true);
    engine::run_probe_grid(sched, probes.len(), cands.len(), |li, ci| {
        let p = &probes[li];
        let (method, bits, group_size, outlier_k) = cands[ci];
        let _probe_span = crate::obs::span_args("planner", || {
            (
                format!("probe {}:{}", method.name(), bits.label()),
                vec![
                    ("layer", p.name.to_string()),
                    ("method", method.name().to_string()),
                    ("bits", bits.label()),
                    ("group_size", group_size.to_string()),
                    ("outlier_k", outlier_k.to_string()),
                ],
            )
        });
        crate::obs::counter("planner.probes", 1);
        let qc = QuantConfig {
            method,
            bits: bits.0,
            error_correction: false,
            group_size,
            outlier_k,
            ..base.clone()
        };
        let lq = method
            .quantizer(bits, &qc)
            .quantize_layer(&LayerCtx::plain(p.x, p.w, sched.channel_threads))?;
        let error = layer_recon_error_gram(p.gram, p.w, &lq.dequant);
        ensure!(
            error.is_finite(),
            "layer '{}': probe {}:{} produced a non-finite error",
            p.name,
            method.name(),
            bits.label()
        );
        Ok(ProbeCell { method, bits, group_size, outlier_k, error })
    })
}

/// Greedy budgeted allocation over a probe error matrix (pure — no
/// quantizer runs, so the property tests drive it directly).
///
/// Every layer starts at the floor width with its best-method probe;
/// upgrades (one width step at a time, best method at the target width)
/// are ordered by marginal gain `Δerror / Δeffective-bits` with the order
/// computed *independently of the budget*, then applied as a prefix that
/// stops at the first upgrade exceeding `budget_bits`. See the module
/// docs for why prefix semantics (rather than skip-and-continue) are
/// required for budget monotonicity.
pub fn allocate(
    probe: &[Vec<ProbeCell>],
    numels: &[usize],
    budget_bits: f64,
) -> Result<Allocation> {
    if probe.is_empty() {
        bail!("allocate: no layers");
    }
    ensure!(
        probe.len() == numels.len(),
        "allocate: {} probe rows vs {} layer sizes",
        probe.len(),
        numels.len()
    );
    if let Some(li) = numels.iter().position(|n| *n == 0) {
        bail!("allocate: layer {li} has zero elements");
    }

    // width ladder from the first layer's cells, ascending
    let mut widths: Vec<BitWidth> = Vec::new();
    for c in &probe[0] {
        if !widths.iter().any(|w| (w.0 - c.bits.0).abs() < 1e-9) {
            widths.push(c.bits);
        }
    }
    widths.sort_by(|a, b| a.0.total_cmp(&b.0));
    if widths.is_empty() {
        bail!("allocate: layer 0 has no probe cells");
    }
    let (nl, nw) = (probe.len(), widths.len());

    // best (lowest-error) cell per (layer, width); earlier candidate wins ties
    let mut best: Vec<Vec<ProbeCell>> = Vec::with_capacity(nl);
    for (li, row) in probe.iter().enumerate() {
        let mut per: Vec<Option<ProbeCell>> = vec![None; nw];
        for c in row {
            ensure!(
                c.error.is_finite(),
                "allocate: layer {li} probe {}:{} error is not finite",
                c.method.name(),
                c.bits.label()
            );
            let wi = widths
                .iter()
                .position(|w| (w.0 - c.bits.0).abs() < 1e-9)
                .ok_or_else(|| {
                    anyhow!(
                        "allocate: layer {li} probes width {} absent from layer 0",
                        c.bits.label()
                    )
                })?;
            match &per[wi] {
                Some(b) if b.error <= c.error => {}
                _ => per[wi] = Some(*c),
            }
        }
        let per: Vec<ProbeCell> = per
            .into_iter()
            .enumerate()
            .map(|(wi, c)| {
                c.ok_or_else(|| {
                    anyhow!("allocate: layer {li} has no probe at {}", widths[wi].label())
                })
            })
            .collect::<Result<_>>()?;
        best.push(per);
    }

    let total: f64 = numels.iter().map(|n| *n as f64).sum();
    let floor_bits = widths[0].0;
    if budget_bits + 1e-9 < floor_bits {
        bail!(
            "budget {budget_bits} bits is below the floor candidate width {} — \
             the smallest achievable effective bits",
            widths[0].label()
        );
    }
    let step_cost = |li: usize, wi: usize| -> f64 {
        numels[li] as f64 * (widths[wi + 1].0 - widths[wi].0) / total
    };

    // budget-independent upgrade sequence: greedy marginal gain simulated
    // with an unbounded budget; ties go to the lower layer index
    let mut cur = vec![0usize; nl];
    let mut seq: Vec<(usize, f64)> = Vec::new();
    loop {
        let mut pick: Option<(f64, usize)> = None;
        for li in 0..nl {
            let wi = cur[li];
            if wi + 1 >= nw {
                continue;
            }
            let gain = (best[li][wi].error - best[li][wi + 1].error) / step_cost(li, wi);
            let better = match pick {
                None => true,
                Some((g, _)) => gain > g,
            };
            if better {
                pick = Some((gain, li));
            }
        }
        let Some((_, li)) = pick else { break };
        seq.push((li, step_cost(li, cur[li])));
        cur[li] += 1;
    }

    // prefix application under the budget
    let mut width_idx = vec![0usize; nl];
    let mut eff = floor_bits;
    let mut applied = 0usize;
    for &(li, cost) in &seq {
        if eff + cost > budget_bits + 1e-9 {
            break;
        }
        eff += cost;
        width_idx[li] += 1;
        applied += 1;
    }

    let chosen: Vec<ProbeCell> = (0..nl).map(|li| best[li][width_idx[li]]).collect();
    let effective_bits = (0..nl)
        .map(|li| numels[li] as f64 * chosen[li].bits.0)
        .sum::<f64>()
        / total;
    Ok(Allocation {
        width_idx,
        chosen,
        effective_bits,
        floor_bits,
        upgrades_applied: applied,
        upgrades_total: seq.len(),
    })
}

/// The full search: probe, allocate, emit. Returns the searched
/// [`QuantPlan`] (base-config pipeline knobs + per-layer `(method, bits)`
/// from the allocation — it round-trips through
/// [`QuantPlan::to_manifest`] like any hand-written plan) and the
/// [`PlannerReport`] describing how the search got there.
pub fn search_plan(
    base: &QuantConfig,
    probes: &[LayerProbe<'_>],
    space: &SearchSpace,
) -> Result<(QuantPlan, PlannerReport)> {
    let _phase = crate::obs::span("phase", "phase.plan_search");
    let cells = probe_errors(base, probes, space)?;
    let grid_bytes: u64 = cells
        .iter()
        .map(|row| (row.len() * std::mem::size_of::<ProbeCell>()) as u64)
        .sum();
    crate::obs::memory::set_resident("planner.probe_grid", grid_bytes);
    let numels: Vec<usize> = probes.iter().map(|p| p.numel).collect();
    let alloc = allocate(&cells, &numels, space.budget_bits)?;

    let assignments: Vec<LayerAssignment> = probes
        .iter()
        .zip(&alloc.chosen)
        .map(|(p, c)| LayerAssignment {
            layer: p.name.to_string(),
            method: c.method,
            bits: c.bits,
            loops: base.loops,
            error_correction: base.error_correction,
            centering: base.centering,
            gptq_damp: base.gptq_damp,
            group_size: c.group_size,
            asymmetric: base.asymmetric,
            outlier_k: c.outlier_k,
        })
        .collect();
    let plan = QuantPlan::from_assignments(base.clone(), assignments)?;

    let report = PlannerReport {
        budget_bits: space.budget_bits,
        probe_count: cells.iter().map(|row| row.len()).sum(),
        layers: probes
            .iter()
            .zip(&cells)
            .zip(&alloc.chosen)
            .map(|((p, row), c)| LayerProbeReport {
                layer: p.name.to_string(),
                numel: p.numel,
                probes: row.clone(),
                chosen: *c,
            })
            .collect(),
        effective_bits: alloc.effective_bits,
        floor_bits: alloc.floor_bits,
        upgrades_applied: alloc.upgrades_applied,
        upgrades_total: alloc.upgrades_total,
    };
    Ok((plan, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Gen;

    fn cell(method: Method, bits: f64, error: f64) -> ProbeCell {
        ProbeCell {
            method,
            bits: BitWidth::parse(&format!("{bits}")).unwrap(),
            group_size: 0,
            outlier_k: 0,
            error,
        }
    }

    #[test]
    fn allocate_hand_checked_two_layers() {
        // widths {2, 4}, equal sizes. Upgrading layer 0 buys 0.4 error
        // per effective bit, layer 1 only 0.05 — at budget 3.0 exactly
        // one upgrade fits and it must go to layer 0.
        let probe = vec![
            vec![cell(Method::Beacon, 2.0, 0.5), cell(Method::Beacon, 4.0, 0.1)],
            vec![cell(Method::Beacon, 2.0, 0.4), cell(Method::Beacon, 4.0, 0.35)],
        ];
        let a = allocate(&probe, &[100, 100], 3.0).unwrap();
        assert_eq!(a.width_idx, vec![1, 0]);
        assert!((a.effective_bits - 3.0).abs() < 1e-12, "{}", a.effective_bits);
        assert_eq!((a.upgrades_applied, a.upgrades_total), (1, 2));
        assert!((a.floor_bits - 2.0).abs() < 1e-12);
        // weighted error 0.1 + 0.4 = 0.5 beats the only other allocation
        // at ≤ 3 effective bits that upgrades anything (0.5 + 0.35)
        let werr: f64 = a.chosen.iter().map(|c| 100.0 * c.error).sum();
        assert!((werr - 50.0).abs() < 1e-9, "{werr}");
    }

    #[test]
    fn allocate_floor_and_top_budgets_are_uniform() {
        let b = Method::Beacon;
        let probe = vec![
            vec![cell(b, 2.0, 0.5), cell(b, 3.0, 0.2), cell(b, 4.0, 0.1)],
            vec![cell(b, 2.0, 0.6), cell(b, 3.0, 0.5), cell(b, 4.0, 0.4)],
            vec![cell(b, 2.0, 0.3), cell(b, 3.0, 0.1), cell(b, 4.0, 0.05)],
        ];
        let sizes = [64usize, 256, 32];
        let floor = allocate(&probe, &sizes, 2.0).unwrap();
        assert_eq!(floor.width_idx, vec![0, 0, 0]);
        assert!((floor.effective_bits - 2.0).abs() < 1e-12);
        let top = allocate(&probe, &sizes, 4.0).unwrap();
        assert_eq!(top.width_idx, vec![2, 2, 2]);
        assert!((top.effective_bits - 4.0).abs() < 1e-9);
        assert_eq!(top.upgrades_applied, top.upgrades_total);
    }

    #[test]
    fn allocate_monotone_in_budget_and_respects_it() {
        // pseudo-random error matrices: widths {2, 2.58, 3, 4}, errors
        // decreasing in bits (scaled per layer)
        let widths = [2.0, 2.58, 3.0, 4.0];
        for seed in 0..10u64 {
            let mut g = Gen { rng: crate::data::rng::SplitMix64::new(seed) };
            let nl = g.usize_in(2, 7);
            let mut probe = Vec::new();
            let mut sizes = Vec::new();
            for _ in 0..nl {
                let scale = g.f64_in(0.1, 1.0);
                let row: Vec<ProbeCell> = widths
                    .iter()
                    .enumerate()
                    .map(|(wi, w)| {
                        cell(Method::Beacon, *w, scale / (wi as f64 + g.f64_in(1.0, 3.0)))
                    })
                    .collect();
                probe.push(row);
                sizes.push(g.usize_in(16, 4096));
            }
            let budgets = [2.0, 2.3, 2.58, 2.8, 3.0, 3.3, 3.7, 4.0];
            let mut prev: Option<Allocation> = None;
            for b in budgets {
                let a = allocate(&probe, &sizes, b).unwrap();
                assert!(
                    a.effective_bits <= b + 1e-9,
                    "seed {seed} budget {b}: effective {}",
                    a.effective_bits
                );
                if let Some(p) = &prev {
                    for li in 0..nl {
                        assert!(
                            a.width_idx[li] >= p.width_idx[li],
                            "seed {seed} budget {b}: layer {li} width decreased"
                        );
                    }
                }
                prev = Some(a);
            }
        }
    }

    #[test]
    fn allocate_picks_best_method_per_width() {
        // comq wins at 2 bits on layer 0, beacon at 4 bits
        let probe = vec![vec![
            cell(Method::Beacon, 2.0, 0.6),
            cell(Method::Comq, 2.0, 0.5),
            cell(Method::Beacon, 4.0, 0.1),
            cell(Method::Comq, 4.0, 0.2),
        ]];
        let low = allocate(&probe, &[10], 2.0).unwrap();
        assert_eq!(low.chosen[0].method, Method::Comq);
        let high = allocate(&probe, &[10], 4.0).unwrap();
        assert_eq!(high.chosen[0].method, Method::Beacon);
    }

    #[test]
    fn allocate_rejects_bad_inputs() {
        let probe = vec![vec![cell(Method::Beacon, 2.0, 0.5)]];
        assert!(allocate(&[], &[], 2.0).is_err());
        assert!(allocate(&probe, &[1, 2], 2.0).is_err());
        assert!(allocate(&probe, &[0], 2.0).is_err());
        // budget below the floor width
        assert!(allocate(&probe, &[10], 1.0).is_err());
        // ragged width grids
        let ragged = vec![
            vec![cell(Method::Beacon, 2.0, 0.5), cell(Method::Beacon, 4.0, 0.2)],
            vec![cell(Method::Beacon, 2.0, 0.5)],
        ];
        assert!(allocate(&ragged, &[10, 10], 3.0).is_err());
        let extra = vec![
            vec![cell(Method::Beacon, 2.0, 0.5)],
            vec![cell(Method::Beacon, 3.0, 0.5)],
        ];
        assert!(allocate(&extra, &[10, 10], 3.0).is_err());
        // non-finite probe error
        let nan = vec![vec![cell(Method::Beacon, 2.0, f64::NAN)]];
        assert!(allocate(&nan, &[10], 2.0).is_err());
    }

    #[test]
    fn search_plan_end_to_end_on_synthetic_layers() {
        // real quantizer probes (RTN — cheap) over synthetic layers; the
        // searched plan must respect the budget and round-trip through
        // the manifest machinery
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(99) };
        let names = ["blocks.0.qkv.w", "blocks.0.fc1.w", "blocks.0.fc2.w"];
        let shapes = [(48usize, 8usize, 12usize), (48, 8, 16), (48, 16, 8)];
        let xs: Vec<Matrix> = shapes
            .iter()
            .map(|&(m, n, _)| Matrix::from_vec(m, n, g.vec_normal(m * n, 1.0)))
            .collect();
        let grams: Vec<Matrix> = xs.iter().map(|x| x.gram()).collect();
        let ws: Vec<Matrix> = shapes
            .iter()
            .map(|&(_, n, np)| Matrix::from_vec(n, np, g.vec_normal(n * np, 0.3)))
            .collect();
        let probes: Vec<LayerProbe> = (0..3)
            .map(|i| LayerProbe {
                name: names[i],
                x: &xs[i],
                gram: &grams[i],
                w: &ws[i],
                numel: ws[i].rows * ws[i].cols,
            })
            .collect();
        let base = QuantConfig { method: Method::Rtn, bits: 2.0, ..QuantConfig::default() };
        let space = SearchSpace::parse(3.0, None, Some("2,3,4")).unwrap();
        let (plan, report) = search_plan(&base, &probes, &space).unwrap();
        assert_eq!(plan.assignments.len(), 3);
        assert!(report.effective_bits <= 3.0 + 1e-9);
        assert!((report.budget_utilization() - report.effective_bits / 3.0).abs() < 1e-12);
        assert_eq!(report.probe_count, 9);
        assert_eq!(report.layers.len(), 3);
        for lr in &report.layers {
            assert_eq!(lr.probes.len(), 3);
            assert!(lr.probes.iter().any(|c| c == &lr.chosen));
        }
        // manifest round-trip against the same layer list
        let lnames: Vec<String> = names.iter().map(|s| s.to_string()).collect();
        let back = QuantPlan::from_manifest(&plan.to_manifest(), &lnames).unwrap();
        assert_eq!(back, plan);

        // determinism across thread counts: same probe matrix bit-for-bit
        let mut base4 = base.clone();
        base4.threads = 4;
        let (plan4, report4) = search_plan(&base4, &probes, &space).unwrap();
        assert_eq!(plan4.assignments, plan.assignments);
        for (a, b) in report.layers.iter().zip(&report4.layers) {
            for (ca, cb) in a.probes.iter().zip(&b.probes) {
                assert_eq!(ca.error.to_bits(), cb.error.to_bits());
            }
        }
    }

    #[test]
    fn search_plan_probes_scenario_axes() {
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(41) };
        let names = ["blocks.0.qkv.w", "blocks.0.fc1.w"];
        let shapes = [(48usize, 20usize, 6usize), (48, 20, 8)];
        let xs: Vec<Matrix> = shapes
            .iter()
            .map(|&(m, n, _)| Matrix::from_vec(m, n, g.vec_normal(m * n, 1.0)))
            .collect();
        let grams: Vec<Matrix> = xs.iter().map(|x| x.gram()).collect();
        let ws: Vec<Matrix> = shapes
            .iter()
            .map(|&(_, n, np)| Matrix::from_vec(n, np, g.vec_normal(n * np, 0.3)))
            .collect();
        let probes: Vec<LayerProbe> = (0..2)
            .map(|i| LayerProbe {
                name: names[i],
                x: &xs[i],
                gram: &grams[i],
                w: &ws[i],
                numel: ws[i].rows * ws[i].cols,
            })
            .collect();
        let base = QuantConfig { method: Method::Rtn, bits: 2.0, ..QuantConfig::default() };
        let mut space = SearchSpace::parse(3.0, None, Some("2,4")).unwrap();
        space.set_group_sizes("0,10").unwrap();
        space.set_outlier_ks("0,1").unwrap();
        let (plan, report) = search_plan(&base, &probes, &space).unwrap();
        // 2 widths × 1 method × 2 group sizes × 2 outlier ks, per layer
        assert_eq!(report.probe_count, 2 * 8);
        for a in &plan.assignments {
            assert!(a.group_size == 0 || a.group_size == 10, "{}", a.group_size);
            assert!(a.outlier_k <= 1, "{}", a.outlier_k);
        }
        // the searched plan round-trips through the manifest with its
        // scenario columns intact
        let lnames: Vec<String> = names.iter().map(|s| s.to_string()).collect();
        let back = QuantPlan::from_manifest(&plan.to_manifest(), &lnames).unwrap();
        assert_eq!(back, plan);

        // gptq's grouped/outlier combinations are dropped from the
        // grid (not probed, not an error)
        let mut space2 =
            SearchSpace::parse(3.0, Some("rtn,gptq"), Some("2,4")).unwrap();
        space2.set_group_sizes("0,10").unwrap();
        let cells = probe_errors(&base, &probes, &space2).unwrap();
        // per width: rtn × {0,10} + gptq × {0} = 3 cells
        assert_eq!(cells[0].len(), 2 * 3);
        assert!(cells[0]
            .iter()
            .all(|c| c.method != Method::Gptq || c.group_size == 0));
    }
}
