//! Tiny subcommand + flag parser (clap stand-in).
//!
//! Grammar: `beacon <subcommand> [--flag value]... [--switch]...`
//! Flags may be given as `--k v` or `--k=v`.
//!
//! Any `QuantConfig` key is accepted as a flag and routed through
//! [`crate::config::QuantConfig::apply_flags`]; notably `--threads N`
//! sets the layer/channel scheduler budget (0 = auto, overriding the
//! `BEACON_THREADS` env var when nonzero).
//!
//! A flag given more than once keeps every occurrence in [`Args::list`]
//! order (the single-value [`Args::get`] view keeps the last) — this is
//! how `--override pattern=spec --override pattern=spec` stacks plan
//! overrides.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    /// last value per flag (the common single-occurrence view)
    pub flags: BTreeMap<String, String>,
    /// every occurrence per flag, in command-line order
    pub repeated: BTreeMap<String, Vec<String>>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        let mut flag = |out: &mut Args, k: String, v: String| {
            out.repeated.entry(k.clone()).or_default().push(v.clone());
            out.flags.insert(k, v);
        };
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    flag(&mut out, k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    flag(&mut out, rest.to_string(), v);
                } else {
                    out.switches.push(rest.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Every occurrence of a repeatable flag, in command-line order.
    pub fn list(&self, key: &str) -> &[String] {
        self.repeated.get(key).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Comma-separated values of a flag, trimmed, empties dropped
    /// (`--plan-bits 2,3,4`); empty when the flag is absent.
    pub fn csv(&self, key: &str) -> Vec<String> {
        self.flags
            .get(key)
            .map(|v| {
                v.split(',')
                    .map(str::trim)
                    .filter(|s| !s.is_empty())
                    .map(String::from)
                    .collect()
            })
            .unwrap_or_default()
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn switch(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("quantize --bits 2 --method beacon --ec");
        assert_eq!(a.subcommand.as_deref(), Some("quantize"));
        assert_eq!(a.f64("bits", 0.0), 2.0);
        assert_eq!(a.str("method", ""), "beacon");
        assert!(a.switch("ec"));
    }

    #[test]
    fn equals_form() {
        let a = parse("run --bits=2.58 --out=dir/x");
        assert_eq!(a.f64("bits", 0.0), 2.58);
        assert_eq!(a.str("out", ""), "dir/x");
    }

    #[test]
    fn trailing_switch() {
        let a = parse("eval --verbose");
        assert!(a.switch("verbose"));
        assert_eq!(a.get("verbose"), None);
    }

    #[test]
    fn positional_args() {
        let a = parse("report table1 table2");
        assert_eq!(a.subcommand.as_deref(), Some("report"));
        assert_eq!(a.positional, vec!["table1", "table2"]);
    }

    #[test]
    fn threads_flag_reaches_quant_config() {
        let a = parse("quantize --threads 4 --bits 2");
        let mut qc = crate::config::QuantConfig::default();
        qc.apply_flags(&a.flags, &a.switches).unwrap();
        assert_eq!(qc.threads, 4);
        assert_eq!(qc.bits, 2.0);
    }

    #[test]
    fn repeated_flags_accumulate() {
        let a = parse(
            "quantize --override blocks.*.qkv.w=beacon:2 --override blocks.*.fc1.w=comq:4 --bits 3",
        );
        assert_eq!(
            a.list("override"),
            &["blocks.*.qkv.w=beacon:2".to_string(), "blocks.*.fc1.w=comq:4".to_string()]
        );
        // single-value view keeps the last occurrence
        assert_eq!(a.get("override"), Some("blocks.*.fc1.w=comq:4"));
        assert!(a.list("missing").is_empty());
        assert_eq!(a.list("bits"), &["3".to_string()]);
    }

    #[test]
    fn csv_flag_splits_and_trims() {
        let a = parse("plan --plan-bits 2,3,4 --plan-methods beacon");
        assert_eq!(a.csv("plan-bits"), vec!["2", "3", "4"]);
        assert_eq!(a.csv("plan-methods"), vec!["beacon"]);
        assert!(a.csv("missing").is_empty());
        let a = Args::parse(["x".to_string(), "--w= 2 , ,4 ".to_string()]);
        assert_eq!(a.csv("w"), vec!["2", "4"]);
    }

    #[test]
    fn defaults() {
        let a = parse("quantize");
        assert_eq!(a.usize("loops", 4), 4);
        assert_eq!(a.f64("bits", 4.0), 4.0);
        assert!(!a.switch("ec"));
    }

    #[test]
    fn trace_flag_forms() {
        // `--trace out.json` carries a path; a bare trailing `--trace`
        // parses as a switch (the binary then picks a default file name)
        let a = parse("quantize --trace out.json");
        assert_eq!(a.get("trace"), Some("out.json"));
        assert!(!a.switch("trace"));
        let a = parse("quantize --trace");
        assert_eq!(a.get("trace"), None);
        assert!(a.switch("trace"));
    }
}
