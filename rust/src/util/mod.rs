//! Small self-contained substrates: JSON parsing, CLI flags, worker pool,
//! property-test driver, bench timing. (The build environment is offline,
//! so these replace serde_json / clap / rayon / proptest / criterion — see
//! DESIGN.md "Environment note".)

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
