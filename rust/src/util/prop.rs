//! Seeded property-test driver (proptest stand-in).
//!
//! `prop_check(cases, |rng| ...)` runs the closure over `cases` independent
//! deterministic splitmix64 streams and reports the failing seed so a
//! reproduction is one function call away.

use crate::data::rng::SplitMix64;

pub struct Gen {
    pub rng: SplitMix64,
}

impl Gen {
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.next_f64()
    }

    /// Standard-normal-ish via the sum of 4 uniforms (Irwin–Hall, rescaled).
    pub fn normal(&mut self) -> f64 {
        let s: f64 = (0..4).map(|_| self.rng.next_f64()).sum();
        (s - 2.0) * (3.0f64).sqrt()
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.rng.next_u64() as usize) % (hi - lo + 1)
    }

    pub fn vec_normal(&mut self, n: usize, scale: f64) -> Vec<f64> {
        (0..n).map(|_| self.normal() * scale).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }
}

/// Run `f` over `cases` deterministic generators; panic with the seed on
/// the first failure (Err(description)).
pub fn prop_check<F>(cases: u64, f: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    for seed in 0..cases {
        let mut g = Gen { rng: SplitMix64::new(0xBEAC0 + seed) };
        if let Err(msg) = f(&mut g) {
            panic!("property failed at seed {seed}: {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Gen { rng: SplitMix64::new(0xBEAC0) };
        let mut b = Gen { rng: SplitMix64::new(0xBEAC0) };
        for _ in 0..10 {
            assert_eq!(a.rng.next_u64(), b.rng.next_u64());
        }
    }

    #[test]
    fn normal_is_centered() {
        let mut g = Gen { rng: SplitMix64::new(7) };
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| g.normal()).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn usize_in_bounds() {
        let mut g = Gen { rng: SplitMix64::new(9) };
        for _ in 0..1000 {
            let v = g.usize_in(3, 7);
            assert!((3..=7).contains(&v));
        }
    }

    #[test]
    #[should_panic(expected = "property failed at seed")]
    fn reports_failing_seed() {
        prop_check(5, |g| {
            if g.rng.next_u64() % 2 == 0 || true {
                Err("always fails".into())
            } else {
                Ok(())
            }
        });
    }
}
