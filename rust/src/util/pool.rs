//! Channel-based scoped worker pool (rayon stand-in).
//!
//! `par_map_indexed` fans a work list over `nthreads` OS threads and
//! returns results in input order. On the single-core CI testbed this
//! defaults to 1 thread (no overhead); on multi-core deployments set
//! `BEACON_THREADS` or pass an explicit count (`QuantConfig::threads`,
//! resolved through [`resolve_threads`]).
//!
//! Result gathering is per-slot: workers ship `(index, value)` pairs over
//! an mpsc channel and the scope's owning thread writes each value into
//! its own `Vec` slot. Unlike the previous `Mutex<Vec<Option<T>>>`
//! design, finished items never contend on one lock, so a channel sweep
//! with thousands of cheap items scales with the thread count instead of
//! serializing on the gather.
//!
//! [`par_map_labeled`] is the instrumented entry point: when the `obs`
//! recorder is enabled it wraps the fan in a span, opens one
//! `pool.worker` span per worker thread and accumulates per-item
//! latencies into a worker-local histogram merged once at worker exit
//! (`"{label}.item_ns"`). When the recorder is disabled the code path
//! is exactly the uninstrumented fan — recording can never perturb the
//! index-ordered gather, so traced runs stay bit-identical.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::Instant;

use crate::obs;

pub fn default_threads() -> usize {
    std::env::var("BEACON_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
}

/// Resolve a configured thread count: `0` means "auto" (the
/// `BEACON_THREADS` env var, falling back to the core count), anything
/// else is taken literally.
pub fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        default_threads()
    }
}

/// Apply `f` to `0..n` (sharing `f` across threads), collecting results in
/// index order. Work-stealing via an atomic cursor, so uneven item costs
/// balance out. Results are deterministic: each `f(i)` runs exactly once
/// and lands in slot `i` regardless of the thread count.
pub fn par_map_indexed<T, F>(n: usize, nthreads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    par_map_labeled("pool", n, nthreads, f)
}

/// [`par_map_indexed`] with a stable label for observability: the fan
/// span, per-worker spans, the `"{label}.items"` counter and the
/// `"{label}.item_ns"` histogram are all keyed off it.
pub fn par_map_labeled<T, F>(label: &'static str, n: usize, nthreads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let nthreads = nthreads.clamp(1, n.max(1));
    if nthreads <= 1 || n <= 1 {
        if !obs::enabled() {
            return (0..n).map(f).collect();
        }
        let _fan = obs::span_args("pool", || {
            (label.to_string(), vec![("items", n.to_string()), ("workers", "1".to_string())])
        });
        let mut hist = obs::Hist::default();
        let out = (0..n)
            .map(|i| {
                let t = Instant::now();
                let r = f(i);
                hist.record(t.elapsed().as_nanos() as u64);
                r
            })
            .collect();
        obs::counter(&format!("{label}.items"), n as u64);
        obs::merge_hist(&format!("{label}.item_ns"), hist);
        return out;
    }
    if !obs::enabled() {
        return fan(n, nthreads, &f, None);
    }
    let _fan = obs::span_args("pool", || {
        (
            label.to_string(),
            vec![("items", n.to_string()), ("workers", nthreads.to_string())],
        )
    });
    fan(n, nthreads, &f, Some(label))
}

/// The shared fan-out: spawn `nthreads` scoped workers over an atomic
/// cursor and gather `(index, value)` pairs into slot order. With
/// `label = Some`, each worker wraps itself in a `pool.worker` span and
/// times items into a worker-local histogram; with `None` this is the
/// original uninstrumented hot path, byte for byte.
fn fan<T, F>(n: usize, nthreads: usize, f: &F, label: Option<&'static str>) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let cursor = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    std::thread::scope(|scope| {
        for _ in 0..nthreads {
            let tx = tx.clone();
            let cursor = &cursor;
            scope.spawn(move || {
                if let Some(label) = label {
                    let worker = obs::span_args("pool.worker", || {
                        (format!("{label}.worker"), Vec::new())
                    });
                    let mut hist = obs::Hist::default();
                    let mut items = 0u64;
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let t = Instant::now();
                        let r = f(i);
                        hist.record(t.elapsed().as_nanos() as u64);
                        items += 1;
                        if tx.send((i, r)).is_err() {
                            break;
                        }
                    }
                    obs::counter(&format!("{label}.items"), items);
                    obs::merge_hist(&format!("{label}.item_ns"), hist);
                    drop(worker);
                } else {
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let r = f(i);
                        if tx.send((i, r)).is_err() {
                            break;
                        }
                    }
                }
            });
        }
        // the scope's owning thread is the single consumer: every result
        // is written once into its own slot, no shared lock on the hot
        // path. The iterator ends when the last worker drops its sender.
        drop(tx);
        for (i, r) in rx {
            out[i] = Some(r);
        }
    });
    out.into_iter()
        .map(|x| x.expect("worker failed to produce result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let r = par_map_indexed(100, 4, |i| i * 2);
        assert_eq!(r, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let r = par_map_indexed(5, 1, |i| i + 1);
        assert_eq!(r, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_work() {
        let r: Vec<usize> = par_map_indexed(0, 4, |i| i);
        assert!(r.is_empty());
    }

    #[test]
    fn uneven_costs_balance() {
        let r = par_map_indexed(20, 3, |i| {
            if i % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            i
        });
        assert_eq!(r, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_matches_serial_bitwise() {
        // f64 work items: the gather must be a pure permutation-free
        // reorder, so any thread count reproduces the serial output.
        let f = |i: usize| (i as f64).sin() * (i as f64).sqrt();
        let serial: Vec<f64> = (0..257).map(f).collect();
        for threads in [2, 4, 8] {
            let par = par_map_indexed(257, threads, f);
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn resolve_threads_semantics() {
        assert_eq!(resolve_threads(3), 3);
        assert_eq!(resolve_threads(0), default_threads());
    }

    #[test]
    fn many_small_items_complete() {
        // regression for the gather path: thousands of near-free items
        // must all be delivered exactly once.
        let r = par_map_indexed(5000, 8, |i| i);
        assert_eq!(r.len(), 5000);
        assert!(r.iter().enumerate().all(|(i, v)| *v == i));
    }
}
