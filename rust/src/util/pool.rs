//! Channel-based scoped worker pool (rayon stand-in).
//!
//! `par_map_indexed` fans a work list over `nthreads` OS threads and
//! returns results in input order. On the single-core CI testbed this
//! defaults to 1 thread (no overhead); on multi-core deployments set
//! `BEACON_THREADS`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

pub fn default_threads() -> usize {
    std::env::var("BEACON_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
}

/// Apply `f` to `0..n` (sharing `f` across threads), collecting results in
/// index order. Work-stealing via an atomic cursor, so uneven item costs
/// balance out.
pub fn par_map_indexed<T, F>(n: usize, nthreads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let nthreads = nthreads.clamp(1, n.max(1));
    if nthreads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let cursor = AtomicUsize::new(0);
    let out: Mutex<Vec<Option<T>>> =
        Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..nthreads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i);
                out.lock().unwrap()[i] = Some(r);
            });
        }
    });
    out.into_inner()
        .unwrap()
        .into_iter()
        .map(|x| x.expect("worker failed to produce result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let r = par_map_indexed(100, 4, |i| i * 2);
        assert_eq!(r, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let r = par_map_indexed(5, 1, |i| i + 1);
        assert_eq!(r, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_work() {
        let r: Vec<usize> = par_map_indexed(0, 4, |i| i);
        assert!(r.is_empty());
    }

    #[test]
    fn uneven_costs_balance() {
        let r = par_map_indexed(20, 3, |i| {
            if i % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            i
        });
        assert_eq!(r, (0..20).collect::<Vec<_>>());
    }
}
