//! Tiny benchmark harness (criterion stand-in) for the `harness = false`
//! bench targets: warmup, fixed-iteration timing, median/p95 reporting.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_ns: u128,
    pub p95_ns: u128,
    pub mean_ns: u128,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} {:>10}  median {:>12}  p95 {:>12}",
            self.name,
            format!("x{}", self.iters),
            fmt_ns(self.median_ns),
            fmt_ns(self.p95_ns),
        );
    }
}

pub fn fmt_ns(ns: u128) -> String {
    if ns >= 1_000_000_000 {
        format!("{:.3} s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.3} ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.3} µs", ns as f64 / 1e3)
    } else {
        format!("{ns} ns")
    }
}

/// Time `f` for `iters` iterations after `warmup` runs; one sample per
/// iteration so the spread is visible.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<u128> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos());
    }
    samples.sort_unstable();
    let median = samples[samples.len() / 2];
    let p95 = samples[(samples.len() * 95 / 100).min(samples.len() - 1)];
    let mean = samples.iter().sum::<u128>() / samples.len() as u128;
    let r = BenchResult {
        name: name.to_string(),
        iters,
        median_ns: median,
        p95_ns: p95,
        mean_ns: mean,
    };
    r.print();
    r
}

/// Prevent the optimizer from discarding a value (std::hint::black_box is
/// stable; this is a convenience re-export point).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_is_positive_and_ordered() {
        let r = bench("noop", 1, 16, || {
            black_box(1 + 1);
        });
        assert!(r.median_ns <= r.p95_ns);
        assert_eq!(r.iters, 16);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500).contains("ns"));
        assert!(fmt_ns(5_000).contains("µs"));
        assert!(fmt_ns(5_000_000).contains("ms"));
        assert!(fmt_ns(5_000_000_000).contains("s"));
    }
}
