//! Minimal recursive-descent JSON parser — enough for the AOT manifest —
//! plus a writer ([`Value::to_json`]) used by the trace exporter and the
//! perf-gate baseline rewrite.
//!
//! Supports the full JSON grammar except `\u` surrogate pairs (the manifest
//! is ASCII). Numbers parse to f64; use [`Value::as_usize`] for counts.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Value {
    pub fn parse(s: &str) -> Result<Value, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; panics with a readable message if the
    /// path is missing — manifests are trusted build products.
    pub fn at(&self, path: &[&str]) -> &Value {
        let mut cur = self;
        for k in path {
            cur = cur
                .get(k)
                .unwrap_or_else(|| panic!("manifest missing key '{k}'"));
        }
        cur
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize to compact JSON. Object keys come out sorted (BTreeMap
    /// order); integral numbers print without a fractional part, so a
    /// parse → to_json round trip of integer-valued documents is exact.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        self.write_json(&mut out);
        out
    }

    fn write_json(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if !n.is_finite() {
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Value::Str(s) => write_json_string(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_json(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_json_string(k, out);
                    out.push(':');
                    v.write_json(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_json_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    s.push(match c {
                        b'"' => '"',
                        b'\\' => '\\',
                        b'/' => '/',
                        b'b' => '\u{8}',
                        b'f' => '\u{c}',
                        b'n' => '\n',
                        b'r' => '\r',
                        b't' => '\t',
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| self.err("bad \\u"))?;
                            self.i += 4;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            char::from_u32(code)
                                .ok_or_else(|| self.err("surrogate \\u unsupported"))?
                        }
                        _ => return Err(self.err("bad escape")),
                    });
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-1.5e2").unwrap(), Value::Num(-150.0));
        assert_eq!(
            Value::parse("\"a\\nb\"").unwrap(),
            Value::Str("a\nb".into())
        );
    }

    #[test]
    fn as_bool_accessor() {
        assert_eq!(Value::Bool(true).as_bool(), Some(true));
        assert_eq!(Value::Bool(false).as_bool(), Some(false));
        assert_eq!(Value::Num(1.0).as_bool(), None);
        assert_eq!(Value::Null.as_bool(), None);
        let v = Value::parse(r#"{"higher_is_better": true}"#).unwrap();
        assert_eq!(v.at(&["higher_is_better"]).as_bool(), Some(true));
    }

    #[test]
    fn parses_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(v.at(&["a"]).as_arr().unwrap().len(), 3);
        assert_eq!(
            v.at(&["a"]).as_arr().unwrap()[2].at(&["b"]).as_str(),
            Some("c")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Value::parse("\"\\u0041\"").unwrap(),
            Value::Str("A".into())
        );
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Value::parse(" {\n\t\"k\" :\r [ 1 , 2 ] } ").unwrap();
        assert_eq!(v.at(&["k"]).as_arr().unwrap().len(), 2);
    }

    #[test]
    fn writer_round_trips() {
        let src = r#"{"a":[1,2.5,{"b":"c"}],"d":{},"e":null,"f":true,"g":-7}"#;
        let v = Value::parse(src).unwrap();
        let out = v.to_json();
        assert_eq!(Value::parse(&out).unwrap(), v);
        // keys are sorted and integers stay integral
        assert_eq!(out, src);
    }

    #[test]
    fn writer_escapes_strings() {
        let v = Value::Str("a\"b\\c\nd\u{1}".to_string());
        let out = v.to_json();
        assert_eq!(out, "\"a\\\"b\\\\c\\nd\\u0001\"");
        assert_eq!(Value::parse(&out).unwrap(), v);
    }

    #[test]
    fn writer_handles_large_integers_exactly() {
        // span timestamps are u64 ns well above 2^32
        let v = Value::Num(123_456_789_012_345.0);
        assert_eq!(v.to_json(), "123456789012345");
        assert_eq!(Value::parse(&v.to_json()).unwrap(), v);
    }

    #[test]
    fn writer_maps_nonfinite_to_null() {
        assert_eq!(Value::Num(f64::NAN).to_json(), "null");
        assert_eq!(Value::Num(f64::INFINITY).to_json(), "null");
    }
}
