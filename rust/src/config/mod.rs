//! Run configuration: quantization method/variant selection and pipeline
//! knobs, parseable from CLI flags and from a simple `key = value` config
//! file (INI-style sections; TOML subset — the offline environment has no
//! serde/toml).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Result};

use crate::quant::alphabet::BitWidth;

pub mod plan;

pub use plan::{glob_match, LayerAssignment, LayerSpec, PlanBuilder, QuantPlan};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    Beacon,
    Gptq,
    Rtn,
    Comq,
}

impl Method {
    pub fn parse(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().as_str() {
            "beacon" => Some(Method::Beacon),
            "gptq" => Some(Method::Gptq),
            "rtn" => Some(Method::Rtn),
            "comq" => Some(Method::Comq),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Beacon => "beacon",
            Method::Gptq => "gptq",
            Method::Rtn => "rtn",
            Method::Comq => "comq",
        }
    }
}

/// When the pipeline recaptures X̃ activations for error correction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecapturePolicy {
    /// before every quantizable layer (max fidelity; paper's Algorithm 1)
    PerLayer,
    /// once per transformer block (4 layers) — cheaper, slightly staler X̃
    PerBlock,
}

#[derive(Debug, Clone, PartialEq)]
pub struct QuantConfig {
    pub method: Method,
    pub bits: f64,
    /// K — Beacon/COMQ refinement sweeps
    pub loops: usize,
    /// Beacon error correction (use X̃ from the partially quantized model)
    pub error_correction: bool,
    /// Beacon asymmetric quantization via centering
    pub centering: bool,
    /// post-quantization LayerNorm tuning
    pub ln_tune: bool,
    pub ln_tune_steps: usize,
    pub ln_tune_lr: f32,
    /// GPTQ Hessian damping factor
    pub gptq_damp: f64,
    /// elements per scale/offset group within a channel (0 = one
    /// scale/offset for the whole channel, the historical convention)
    pub group_size: usize,
    /// asymmetric (zero-point) grids: per-group centering for Beacon;
    /// the min-max family (RTN/GPTQ/COMQ) is natively asymmetric
    pub asymmetric: bool,
    /// keep the top-k magnitude weights per channel exact in an f32
    /// sidecar and quantize the rest (0 = dense)
    pub outlier_k: usize,
    pub recapture: RecapturePolicy,
    /// calibration images to use (0 = all available)
    pub calib_count: usize,
    /// evaluation images to use (0 = all available)
    pub eval_count: usize,
    /// thread budget for the layer/channel scheduler (0 = auto: the
    /// `BEACON_THREADS` env var, falling back to the core count). Output
    /// is bit-identical at any value.
    pub threads: usize,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig {
            method: Method::Beacon,
            bits: 2.0,
            loops: 4,
            error_correction: false,
            centering: false,
            ln_tune: false,
            ln_tune_steps: 30,
            ln_tune_lr: 0.05,
            gptq_damp: 0.01,
            group_size: 0,
            asymmetric: false,
            outlier_k: 0,
            recapture: RecapturePolicy::PerLayer,
            calib_count: 0,
            eval_count: 0,
            threads: 0,
        }
    }
}

impl QuantConfig {
    /// The validated bit width. Errs (rather than panicking) on an
    /// unsupported `bits` value — reachable by direct struct construction,
    /// which bypasses [`QuantConfig::set`] validation; plan building
    /// ([`PlanBuilder::build`]) surfaces this error before any layer runs.
    pub fn bit_width(&self) -> Result<BitWidth> {
        BitWidth::parse(&format!("{}", self.bits))
            .ok_or_else(|| anyhow::anyhow!("unsupported bit width {}", self.bits))
    }

    /// Human label like "beacon-2bit+ec+centering".
    pub fn label(&self) -> String {
        let bits_label = match self.bit_width() {
            Ok(b) => b.label(),
            Err(_) => format!("{}-bit(unsupported)", self.bits),
        };
        let mut s = format!("{}-{}", self.method.name(), bits_label);
        if self.method == Method::Beacon {
            if self.error_correction {
                s.push_str("+ec");
            }
            if self.centering {
                s.push_str("+centering");
            }
            if self.ln_tune {
                s.push_str("+ln");
            }
        }
        // scenario axes apply to every method; the default scenario adds
        // nothing, so historical labels are unchanged
        if self.group_size > 0 {
            s.push_str(&format!("+g{}", self.group_size));
        }
        if self.asymmetric {
            s.push_str("+asym");
        }
        if self.outlier_k > 0 {
            s.push_str(&format!("+k{}", self.outlier_k));
        }
        s
    }

    /// Every config field as `(key, value)` pairs, in declaration order,
    /// such that feeding them back through [`QuantConfig::set`]
    /// reproduces this exact config (the `[quant]` section of a
    /// [`QuantPlan`] manifest).
    pub fn to_kv(&self) -> Vec<(String, String)> {
        let kv = |k: &str, v: String| (k.to_string(), v);
        vec![
            kv("method", self.method.name().to_string()),
            kv("bits", format!("{}", self.bits)),
            kv("loops", self.loops.to_string()),
            kv("error_correction", self.error_correction.to_string()),
            kv("centering", self.centering.to_string()),
            kv("ln_tune", self.ln_tune.to_string()),
            kv("ln_tune_steps", self.ln_tune_steps.to_string()),
            kv("ln_tune_lr", format!("{}", self.ln_tune_lr)),
            kv("gptq_damp", format!("{}", self.gptq_damp)),
            kv("group_size", self.group_size.to_string()),
            kv("asymmetric", self.asymmetric.to_string()),
            kv("outlier_k", self.outlier_k.to_string()),
            kv(
                "recapture",
                match self.recapture {
                    RecapturePolicy::PerLayer => "layer".to_string(),
                    RecapturePolicy::PerBlock => "block".to_string(),
                },
            ),
            kv("calib_count", self.calib_count.to_string()),
            kv("eval_count", self.eval_count.to_string()),
            kv("threads", self.threads.to_string()),
        ]
    }

    /// Apply `key = value` overrides (config-file entries or CLI flags).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "method" => {
                self.method = Method::parse(value)
                    .ok_or_else(|| anyhow::anyhow!("unknown method '{value}'"))?
            }
            "bits" => {
                self.bits = value.parse()?;
                // validate early
                let _ = BitWidth::parse(value)
                    .ok_or_else(|| anyhow::anyhow!("unsupported bits '{value}'"))?;
            }
            "loops" => self.loops = value.parse()?,
            "error_correction" | "ec" => self.error_correction = parse_bool(value)?,
            "centering" => self.centering = parse_bool(value)?,
            "ln_tune" => self.ln_tune = parse_bool(value)?,
            "ln_tune_steps" => self.ln_tune_steps = value.parse()?,
            "ln_tune_lr" => self.ln_tune_lr = value.parse()?,
            "gptq_damp" => self.gptq_damp = value.parse()?,
            "group_size" => {
                let g: usize = value.parse()?;
                if g == 1 {
                    bail!("group_size must be 0 (per-channel) or >= 2, got 1");
                }
                self.group_size = g;
            }
            "asymmetric" | "asym" => self.asymmetric = parse_bool(value)?,
            "outlier_k" => self.outlier_k = value.parse()?,
            "calib_count" => self.calib_count = value.parse()?,
            "eval_count" => self.eval_count = value.parse()?,
            "threads" => self.threads = value.parse()?,
            "recapture" => {
                self.recapture = match value {
                    "layer" => RecapturePolicy::PerLayer,
                    "block" => RecapturePolicy::PerBlock,
                    _ => bail!("recapture must be 'layer' or 'block'"),
                }
            }
            _ => bail!("unknown config key '{key}'"),
        }
        Ok(())
    }

    /// Load from an INI-style file: `key = value` lines, `#` comments,
    /// optional `[quant]` section header (other sections ignored).
    pub fn from_file(path: &Path) -> Result<QuantConfig> {
        let text = std::fs::read_to_string(path)?;
        let mut cfg = QuantConfig::default();
        let mut section = String::from("quant");
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            if section != "quant" {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
            cfg.set(k.trim(), v.trim())?;
        }
        Ok(cfg)
    }

    /// Parse all recognized keys out of a flag map (unknown keys are left
    /// for the caller).
    pub fn apply_flags(&mut self, flags: &BTreeMap<String, String>, switches: &[String]) -> Result<()> {
        for (k, v) in flags {
            if self.is_known_key(k) {
                self.set(k, v)?;
            }
        }
        for s in switches {
            if self.is_known_key(s) {
                self.set(s, "true")?;
            }
        }
        Ok(())
    }

    fn is_known_key(&self, k: &str) -> bool {
        matches!(
            k,
            "method" | "bits" | "loops" | "error_correction" | "ec"
                | "centering" | "ln_tune" | "ln_tune_steps" | "ln_tune_lr"
                | "gptq_damp" | "group_size" | "asymmetric" | "asym"
                | "outlier_k" | "calib_count" | "eval_count" | "recapture"
                | "threads"
        )
    }
}

/// The planner's search space: which `(method, bits)` assignments are
/// probed per layer and the size-weighted effective-bits budget the
/// greedy allocation must respect (`--auto-plan --budget-bits B`).
///
/// Empty `methods`/`widths` mean "default": the base config's method and
/// every supported width ([`BitWidth::ALL`]). Resolution happens in
/// [`crate::coordinator::planner::search_plan`] so one `SearchSpace`
/// value works against any base config.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchSpace {
    /// candidate methods (empty = just the base config's method)
    pub methods: Vec<Method>,
    /// candidate bit widths (empty = [`BitWidth::ALL`])
    pub widths: Vec<BitWidth>,
    /// candidate group sizes (empty = just the base config's group_size)
    pub group_sizes: Vec<usize>,
    /// candidate per-channel outlier counts (empty = just the base's)
    pub outlier_ks: Vec<usize>,
    /// size-weighted effective bits/weight ceiling for the emitted plan
    pub budget_bits: f64,
}

impl SearchSpace {
    /// Default grid at the given budget: base method × all widths.
    pub fn new(budget_bits: f64) -> SearchSpace {
        SearchSpace {
            methods: Vec::new(),
            widths: Vec::new(),
            group_sizes: Vec::new(),
            outlier_ks: Vec::new(),
            budget_bits,
        }
    }

    /// Parse from the CLI surface: comma-separated method and width lists
    /// (either may be `None` to keep the default).
    pub fn parse(
        budget_bits: f64,
        methods_csv: Option<&str>,
        widths_csv: Option<&str>,
    ) -> Result<SearchSpace> {
        let mut space = SearchSpace::new(budget_bits);
        if let Some(csv) = methods_csv {
            for part in csv.split(',').map(str::trim).filter(|s| !s.is_empty()) {
                space.methods.push(
                    Method::parse(part)
                        .ok_or_else(|| anyhow::anyhow!("unknown method '{part}'"))?,
                );
            }
        }
        if let Some(csv) = widths_csv {
            for part in csv.split(',').map(str::trim).filter(|s| !s.is_empty()) {
                space.widths.push(
                    BitWidth::parse(part)
                        .ok_or_else(|| anyhow::anyhow!("unsupported bits '{part}'"))?,
                );
            }
        }
        space.validate()?;
        Ok(space)
    }

    /// Structural validation (the planner re-checks the budget against the
    /// resolved floor width, which needs the concrete candidate grid).
    pub fn validate(&self) -> Result<()> {
        if !self.budget_bits.is_finite() || self.budget_bits <= 0.0 {
            bail!("--budget-bits must be a positive number, got {}", self.budget_bits);
        }
        Ok(())
    }

    /// The candidate widths, resolved (default grid if unset), deduped and
    /// sorted ascending — the upgrade ladder the greedy allocation climbs.
    pub fn sorted_widths(&self) -> Vec<BitWidth> {
        let mut widths: Vec<BitWidth> = if self.widths.is_empty() {
            BitWidth::ALL.to_vec()
        } else {
            self.widths.clone()
        };
        widths.sort_by(|a, b| a.0.total_cmp(&b.0));
        widths.dedup_by(|a, b| (a.0 - b.0).abs() < 1e-9);
        widths
    }

    /// The candidate methods, resolved against a base config.
    pub fn resolved_methods(&self, base: &QuantConfig) -> Vec<Method> {
        if self.methods.is_empty() {
            vec![base.method]
        } else {
            self.methods.clone()
        }
    }

    /// Add candidate group sizes from a CSV (`--plan-groups 0,16,32`).
    pub fn set_group_sizes(&mut self, csv: &str) -> Result<()> {
        for part in csv.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let g: usize = part
                .parse()
                .map_err(|_| anyhow::anyhow!("bad group size '{part}'"))?;
            if g == 1 {
                bail!("group size must be 0 (per-channel) or >= 2, got 1");
            }
            self.group_sizes.push(g);
        }
        Ok(())
    }

    /// Add candidate outlier counts from a CSV (`--plan-outliers 0,2`).
    pub fn set_outlier_ks(&mut self, csv: &str) -> Result<()> {
        for part in csv.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let k: usize = part
                .parse()
                .map_err(|_| anyhow::anyhow!("bad outlier count '{part}'"))?;
            self.outlier_ks.push(k);
        }
        Ok(())
    }

    /// The candidate group sizes, resolved against a base config.
    pub fn resolved_group_sizes(&self, base: &QuantConfig) -> Vec<usize> {
        if self.group_sizes.is_empty() {
            vec![base.group_size]
        } else {
            let mut g = self.group_sizes.clone();
            g.sort_unstable();
            g.dedup();
            g
        }
    }

    /// The candidate outlier counts, resolved against a base config.
    pub fn resolved_outlier_ks(&self, base: &QuantConfig) -> Vec<usize> {
        if self.outlier_ks.is_empty() {
            vec![base.outlier_k]
        } else {
            let mut k = self.outlier_ks.clone();
            k.sort_unstable();
            k.dedup();
            k
        }
    }
}

pub(crate) fn parse_bool(v: &str) -> Result<bool> {
    match v.to_ascii_lowercase().as_str() {
        "true" | "1" | "yes" | "on" => Ok(true),
        "false" | "0" | "no" | "off" => Ok(false),
        _ => bail!("expected bool, got '{v}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = QuantConfig::default();
        assert_eq!(c.method, Method::Beacon);
        assert_eq!(c.loops, 4);
        assert!(!c.error_correction);
    }

    #[test]
    fn set_and_label() {
        let mut c = QuantConfig::default();
        c.set("bits", "1.58").unwrap();
        c.set("ec", "true").unwrap();
        c.set("centering", "on").unwrap();
        assert_eq!(c.label(), "beacon-1.58-bit+ec+centering");
    }

    #[test]
    fn threads_key_parses() {
        let mut c = QuantConfig::default();
        assert_eq!(c.threads, 0, "default is auto");
        c.set("threads", "4").unwrap();
        assert_eq!(c.threads, 4);
        assert!(c.set("threads", "x").is_err());
        // threads never shows up in the run label (it does not affect
        // the result — output is bit-identical at any thread count)
        assert!(!c.label().contains("threads"));
    }

    #[test]
    fn rejects_unknown() {
        let mut c = QuantConfig::default();
        assert!(c.set("bogus", "1").is_err());
        assert!(c.set("bits", "7.3").is_err());
        assert!(c.set("method", "awq").is_err());
    }

    #[test]
    fn config_file_roundtrip() {
        let dir = std::env::temp_dir().join("beacon_ptq_cfg_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("run.cfg");
        std::fs::write(
            &p,
            "# table-1 column 3\n[quant]\nmethod = beacon\nbits = 2.58\nloops = 4\nec = true\ncentering = true\n\n[ignored]\nfoo = bar\n",
        )
        .unwrap();
        let c = QuantConfig::from_file(&p).unwrap();
        assert_eq!(c.bits, 2.58);
        assert!(c.error_correction && c.centering);
        assert_eq!(c.method, Method::Beacon);
    }

    #[test]
    fn bad_file_line_reported() {
        let dir = std::env::temp_dir().join("beacon_ptq_cfg_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.cfg");
        std::fs::write(&p, "not a kv line\n").unwrap();
        let e = QuantConfig::from_file(&p).unwrap_err().to_string();
        assert!(e.contains("line 1"), "{e}");
    }

    #[test]
    fn bit_width_is_fallible_not_panicking() {
        // direct struct construction bypasses set() validation — the old
        // bit_width() panicked here; now the error flows to plan building
        let c = QuantConfig { bits: 7.3, ..QuantConfig::default() };
        assert!(c.bit_width().is_err());
        assert!(c.label().contains("unsupported"), "{}", c.label());
        assert_eq!(QuantConfig::default().bit_width().unwrap().0, 2.0);
    }

    #[test]
    fn to_kv_round_trips_through_set() {
        let mut c = QuantConfig::default();
        c.set("method", "comq").unwrap();
        c.set("bits", "2.58").unwrap();
        c.set("ec", "true").unwrap();
        c.set("recapture", "block").unwrap();
        c.set("threads", "3").unwrap();
        let mut back = QuantConfig::default();
        for (k, v) in c.to_kv() {
            back.set(&k, &v).unwrap();
        }
        assert_eq!(back, c);
    }

    #[test]
    fn method_parse() {
        assert_eq!(Method::parse("GPTQ"), Some(Method::Gptq));
        assert_eq!(Method::parse("beacon"), Some(Method::Beacon));
        assert_eq!(Method::parse("x"), None);
    }

    #[test]
    fn search_space_defaults_and_parse() {
        let s = SearchSpace::new(2.5);
        assert!(s.methods.is_empty() && s.widths.is_empty());
        let base = QuantConfig { method: Method::Comq, ..QuantConfig::default() };
        assert_eq!(s.resolved_methods(&base), vec![Method::Comq]);
        let w = s.sorted_widths();
        assert_eq!(w.len(), BitWidth::ALL.len());
        assert!(w.windows(2).all(|p| p[0].0 < p[1].0));

        let s = SearchSpace::parse(3.0, Some("beacon, comq"), Some("2,4,2")).unwrap();
        assert_eq!(s.methods, vec![Method::Beacon, Method::Comq]);
        // duplicate widths collapse, sorted ascending
        let w = s.sorted_widths();
        assert_eq!(w.iter().map(|b| b.0).collect::<Vec<_>>(), vec![2.0, 4.0]);
    }

    #[test]
    fn scenario_keys_parse_and_label() {
        let mut c = QuantConfig::default();
        assert_eq!(c.group_size, 0);
        assert!(!c.asymmetric);
        assert_eq!(c.outlier_k, 0);
        c.set("group_size", "16").unwrap();
        c.set("asym", "true").unwrap();
        c.set("outlier_k", "2").unwrap();
        assert_eq!(c.label(), "beacon-2-bit+g16+asym+k2");
        assert!(c.set("group_size", "1").is_err(), "degenerate group size");
        // round-trips through to_kv/set like every other field
        let mut back = QuantConfig::default();
        for (k, v) in c.to_kv() {
            back.set(&k, &v).unwrap();
        }
        assert_eq!(back, c);
    }

    #[test]
    fn search_space_scenario_axes() {
        let mut s = SearchSpace::new(3.0);
        let base = QuantConfig::default();
        // empty = base's values only
        assert_eq!(s.resolved_group_sizes(&base), vec![0]);
        assert_eq!(s.resolved_outlier_ks(&base), vec![0]);
        s.set_group_sizes("32, 0,16").unwrap();
        s.set_outlier_ks("2,0,2").unwrap();
        // sorted + deduped
        assert_eq!(s.resolved_group_sizes(&base), vec![0, 16, 32]);
        assert_eq!(s.resolved_outlier_ks(&base), vec![0, 2]);
        assert!(SearchSpace::new(3.0).set_group_sizes("1").is_err());
        assert!(SearchSpace::new(3.0).set_group_sizes("x").is_err());
        assert!(SearchSpace::new(3.0).set_outlier_ks("-1").is_err());
    }

    #[test]
    fn search_space_rejects_garbage() {
        assert!(SearchSpace::parse(0.0, None, None).is_err());
        assert!(SearchSpace::parse(-2.0, None, None).is_err());
        assert!(SearchSpace::parse(f64::NAN, None, None).is_err());
        assert!(SearchSpace::parse(2.5, Some("awq"), None).is_err());
        assert!(SearchSpace::parse(2.5, None, Some("7.3")).is_err());
    }
}
