//! The compiled per-layer quantization plan — the unit the pipeline
//! consumes.
//!
//! Beacon's scale recovery is per-channel and tuning-free, which makes
//! every layer an independent quantization decision. A [`QuantPlan`]
//! makes that decision explicit: one resolved
//! `(layer, method, bits, opts)` assignment per quantizable layer,
//! compiled from [`QuantConfig`] defaults plus an ordered list of
//! glob-style overrides (last match wins):
//!
//! ```no_run
//! use beacon_ptq::config::{PlanBuilder, QuantConfig};
//!
//! let layers: Vec<String> = vec![/* model's quantizable layer names */];
//! let plan = PlanBuilder::uniform(&QuantConfig::default())
//!     .override_layers("blocks.*.qkv.w", "beacon:2+ec")
//!     .unwrap()
//!     .override_layers("blocks.*.fc?.w", "comq:4")
//!     .unwrap()
//!     .build(&layers)
//!     .unwrap();
//! assert_eq!(plan.assignments.len(), layers.len());
//! ```
//!
//! Validation happens at `build` time, not mid-run: a pattern matching
//! zero layers, an unsupported bit width (including one smuggled past
//! [`QuantConfig::set`] by direct struct construction), or a malformed
//! spec string all fail before any weight is touched.
//!
//! Plans serialize to a `key = value` manifest (`[quant]` base section +
//! one `[layer "pattern"]` section per override) via
//! [`QuantPlan::to_manifest`] / [`QuantPlan::from_manifest`], so every
//! run — uniform or mixed — is reproducible from one file. The same
//! format doubles as the run config file: [`PlanBuilder::from_file`]
//! accepts both hand-written pattern sections and emitted manifests.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::quant::alphabet::BitWidth;

use super::{Method, QuantConfig};

/// Glob match with `*` (any run of characters, including `.`) and `?`
/// (exactly one character). Anchored at both ends: `blocks.*.fc1.w`
/// matches `blocks.3.fc1.w` but not `xblocks.3.fc1.w2`.
pub fn glob_match(pattern: &str, name: &str) -> bool {
    let p: Vec<char> = pattern.chars().collect();
    let n: Vec<char> = name.chars().collect();
    let (mut pi, mut ni) = (0usize, 0usize);
    let mut star: Option<usize> = None;
    let mut mark = 0usize;
    while ni < n.len() {
        if pi < p.len() && (p[pi] == '?' || p[pi] == n[ni]) {
            pi += 1;
            ni += 1;
        } else if pi < p.len() && p[pi] == '*' {
            star = Some(pi);
            mark = ni;
            pi += 1;
        } else if let Some(s) = star {
            pi = s + 1;
            mark += 1;
            ni = mark;
        } else {
            return false;
        }
    }
    while pi < p.len() && p[pi] == '*' {
        pi += 1;
    }
    pi == p.len()
}

/// A partial per-layer override: only the fields a spec names deviate
/// from the base config (or from an earlier matching override).
///
/// Compact string form: `method[:bits][+flag]...` where flags are
/// `ec`/`noec`, `centering`/`nocentering`, `g<N>` (group size, `g0` =
/// per-channel), `asym`/`sym`, `k<N>` (outlier count), `loops=K`,
/// `damp=F`. The method is optional when bits are given (`:4` re-bits
/// whatever method an earlier match picked). Examples: `comq:4`,
/// `beacon:8+centering`, `rtn`, `:2+loops=6`, `beacon:3+g16+asym+k2`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LayerSpec {
    pub method: Option<Method>,
    pub bits: Option<BitWidth>,
    pub loops: Option<usize>,
    pub error_correction: Option<bool>,
    pub centering: Option<bool>,
    pub gptq_damp: Option<f64>,
    pub group_size: Option<usize>,
    pub asymmetric: Option<bool>,
    pub outlier_k: Option<usize>,
}

impl LayerSpec {
    /// Parse the compact `method[:bits][+flag]...` form.
    pub fn parse(s: &str) -> Result<LayerSpec> {
        let s = s.trim();
        if s.is_empty() {
            bail!("empty layer spec");
        }
        let mut spec = LayerSpec::default();
        let mut parts = s.split('+');
        let head = parts.next().unwrap().trim();
        let (method_s, bits_s) = match head.split_once(':') {
            Some((m, b)) => (m.trim(), Some(b.trim())),
            None => (head, None),
        };
        if !method_s.is_empty() {
            spec.method = Some(
                Method::parse(method_s)
                    .ok_or_else(|| anyhow::anyhow!("unknown method '{method_s}' in spec '{s}'"))?,
            );
        }
        if let Some(b) = bits_s {
            spec.set_key("bits", b).with_context(|| format!("in spec '{s}'"))?;
        }
        if spec.method.is_none() && spec.bits.is_none() {
            bail!("layer spec '{s}' names neither a method nor a bit width");
        }
        for flag in parts {
            let flag = flag.trim();
            match flag {
                "ec" => spec.error_correction = Some(true),
                "noec" => spec.error_correction = Some(false),
                "centering" => spec.centering = Some(true),
                "nocentering" => spec.centering = Some(false),
                "asym" => spec.asymmetric = Some(true),
                "sym" => spec.asymmetric = Some(false),
                // g<N> / k<N> shorthands (the scenario axes); any other
                // g…/k… string still falls through to key=value / unknown
                _ if flag.len() > 1
                    && flag.starts_with('g')
                    && flag[1..].bytes().all(|b| b.is_ascii_digit()) =>
                {
                    spec.set_key("group_size", &flag[1..])
                        .with_context(|| format!("in spec '{s}'"))?
                }
                _ if flag.len() > 1
                    && flag.starts_with('k')
                    && flag[1..].bytes().all(|b| b.is_ascii_digit()) =>
                {
                    spec.set_key("outlier_k", &flag[1..])
                        .with_context(|| format!("in spec '{s}'"))?
                }
                _ => match flag.split_once('=') {
                    Some((k, v)) => spec
                        .set_key(k.trim(), v.trim())
                        .with_context(|| format!("in spec '{s}'"))?,
                    None => bail!("unknown flag '+{flag}' in spec '{s}'"),
                },
            }
        }
        Ok(spec)
    }

    /// Apply one `key = value` entry (the `[layer "…"]` section form).
    pub fn set_key(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "spec" => {
                let parsed = LayerSpec::parse(value)?;
                self.merge(&parsed);
            }
            "method" => {
                self.method = Some(
                    Method::parse(value)
                        .ok_or_else(|| anyhow::anyhow!("unknown method '{value}'"))?,
                )
            }
            "bits" => {
                self.bits = Some(
                    BitWidth::parse(value)
                        .ok_or_else(|| anyhow::anyhow!("unsupported bits '{value}'"))?,
                )
            }
            "loops" => self.loops = Some(value.parse().context("loops")?),
            "error_correction" | "ec" => {
                self.error_correction = Some(super::parse_bool(value)?)
            }
            "centering" => self.centering = Some(super::parse_bool(value)?),
            "gptq_damp" | "damp" => self.gptq_damp = Some(value.parse().context("damp")?),
            "group_size" => {
                let g: usize = value.parse().context("group_size")?;
                if g == 1 {
                    bail!("group_size must be 0 (per-channel) or >= 2, got 1");
                }
                self.group_size = Some(g);
            }
            "asymmetric" | "asym" => self.asymmetric = Some(super::parse_bool(value)?),
            "outlier_k" => self.outlier_k = Some(value.parse().context("outlier_k")?),
            _ => bail!("unknown layer-override key '{key}'"),
        }
        Ok(())
    }

    /// Overlay `other`'s set fields onto self (later spec wins).
    pub fn merge(&mut self, other: &LayerSpec) {
        if other.method.is_some() {
            self.method = other.method;
        }
        if other.bits.is_some() {
            self.bits = other.bits;
        }
        if other.loops.is_some() {
            self.loops = other.loops;
        }
        if other.error_correction.is_some() {
            self.error_correction = other.error_correction;
        }
        if other.centering.is_some() {
            self.centering = other.centering;
        }
        if other.gptq_damp.is_some() {
            self.gptq_damp = other.gptq_damp;
        }
        if other.group_size.is_some() {
            self.group_size = other.group_size;
        }
        if other.asymmetric.is_some() {
            self.asymmetric = other.asymmetric;
        }
        if other.outlier_k.is_some() {
            self.outlier_k = other.outlier_k;
        }
    }
}

/// One fully resolved per-layer assignment: everything the engine needs
/// to construct the layer's quantizer.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerAssignment {
    /// concrete layer name (no patterns at this stage)
    pub layer: String,
    pub method: Method,
    pub bits: BitWidth,
    pub loops: usize,
    pub error_correction: bool,
    pub centering: bool,
    pub gptq_damp: f64,
    pub group_size: usize,
    pub asymmetric: bool,
    pub outlier_k: usize,
}

impl LayerAssignment {
    fn from_base(layer: &str, base: &QuantConfig) -> Result<LayerAssignment> {
        Ok(LayerAssignment {
            layer: layer.to_string(),
            method: base.method,
            bits: base.bit_width().context("base config")?,
            loops: base.loops,
            error_correction: base.error_correction,
            centering: base.centering,
            gptq_damp: base.gptq_damp,
            group_size: base.group_size,
            asymmetric: base.asymmetric,
            outlier_k: base.outlier_k,
        })
    }

    fn apply(&mut self, spec: &LayerSpec) {
        if let Some(m) = spec.method {
            self.method = m;
        }
        if let Some(b) = spec.bits {
            self.bits = b;
        }
        if let Some(l) = spec.loops {
            self.loops = l;
        }
        if let Some(e) = spec.error_correction {
            self.error_correction = e;
        }
        if let Some(c) = spec.centering {
            self.centering = c;
        }
        if let Some(d) = spec.gptq_damp {
            self.gptq_damp = d;
        }
        if let Some(g) = spec.group_size {
            self.group_size = g;
        }
        if let Some(a) = spec.asymmetric {
            self.asymmetric = a;
        }
        if let Some(k) = spec.outlier_k {
            self.outlier_k = k;
        }
    }

    /// The assignment merged back into a full config (pipeline-level
    /// knobs — LN tuning, recapture policy, counts, threads — come from
    /// `base`). This is what `Method::quantizer` consumes.
    pub fn to_config(&self, base: &QuantConfig) -> QuantConfig {
        QuantConfig {
            method: self.method,
            bits: self.bits.0,
            loops: self.loops,
            error_correction: self.error_correction,
            centering: self.centering,
            gptq_damp: self.gptq_damp,
            group_size: self.group_size,
            asymmetric: self.asymmetric,
            outlier_k: self.outlier_k,
            ..base.clone()
        }
    }

    /// Method×bits tag used in labels and report rows ("comq-4-bit",
    /// "beacon-3-bit+g16+asym+k2").
    pub fn tag(&self) -> String {
        let mut s = format!("{}-{}", self.method.name(), self.bits.label());
        if self.group_size > 0 {
            s.push_str(&format!("+g{}", self.group_size));
        }
        if self.asymmetric {
            s.push_str("+asym");
        }
        if self.outlier_k > 0 {
            s.push_str(&format!("+k{}", self.outlier_k));
        }
        s
    }

    /// Whether every method/bits/opts field equals `other`'s (the layer
    /// name is ignored — used to detect uniform plans).
    fn same_recipe(&self, other: &LayerAssignment) -> bool {
        self.method == other.method
            && self.bits == other.bits
            && self.loops == other.loops
            && self.error_correction == other.error_correction
            && self.centering == other.centering
            && self.gptq_damp == other.gptq_damp
            && self.group_size == other.group_size
            && self.asymmetric == other.asymmetric
            && self.outlier_k == other.outlier_k
    }

    /// Structural validation of the scenario axes — shared by
    /// [`PlanBuilder::build`] and [`QuantPlan::from_assignments`] so a
    /// bad combination fails before any weight is touched.
    fn validate_scenario(&self) -> Result<()> {
        if self.group_size == 1 {
            bail!(
                "layer '{}': group_size must be 0 (per-channel) or >= 2",
                self.layer
            );
        }
        if self.method == Method::Gptq && (self.group_size > 0 || self.outlier_k > 0) {
            bail!(
                "layer '{}': gptq supports only the dense per-channel scenario \
                 (drop the +g/+k flags or pick beacon/rtn/comq)",
                self.layer
            );
        }
        Ok(())
    }
}

/// Fluent compiler from `QuantConfig` defaults + ordered glob overrides
/// to a validated [`QuantPlan`].
#[derive(Debug, Clone)]
pub struct PlanBuilder {
    base: QuantConfig,
    overrides: Vec<(String, LayerSpec)>,
}

impl PlanBuilder {
    /// Start from a uniform plan: every layer gets `cfg`'s method/bits.
    pub fn uniform(cfg: &QuantConfig) -> PlanBuilder {
        PlanBuilder { base: cfg.clone(), overrides: Vec::new() }
    }

    pub fn base(&self) -> &QuantConfig {
        &self.base
    }

    /// Mutable access to the defaults (CLI flag overlay, etc.).
    pub fn base_mut(&mut self) -> &mut QuantConfig {
        &mut self.base
    }

    pub fn overrides(&self) -> &[(String, LayerSpec)] {
        &self.overrides
    }

    /// Append a glob override from its compact string form. Spec errors
    /// surface here; unmatched patterns surface at [`PlanBuilder::build`].
    pub fn add_override(&mut self, pattern: &str, spec: &str) -> Result<()> {
        let pattern = pattern.trim();
        if pattern.is_empty() {
            bail!("empty layer-override pattern");
        }
        let parsed = LayerSpec::parse(spec)
            .with_context(|| format!("override '{pattern}'"))?;
        self.overrides.push((pattern.to_string(), parsed));
        Ok(())
    }

    /// Fluent form of [`PlanBuilder::add_override`].
    pub fn override_layers(mut self, pattern: &str, spec: &str) -> Result<PlanBuilder> {
        self.add_override(pattern, spec)?;
        Ok(self)
    }

    /// Parse a config file / plan manifest: `[quant]` keys feed the base
    /// config, each `[layer "pattern"]` section appends one override
    /// (section order preserved — last match wins at build time).
    pub fn from_manifest_text(text: &str) -> Result<PlanBuilder> {
        let mut builder = PlanBuilder::uniform(&QuantConfig::default());
        // section = None → outside any recognized section; Some(None) →
        // [quant]; Some(Some(i)) → i-th [layer "…"] override.
        let mut section: Option<Option<usize>> = Some(None);
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                let name = name.trim();
                if name == "quant" {
                    section = Some(None);
                } else if let Some(rest) = name.strip_prefix("layer") {
                    let pat = rest
                        .trim()
                        .strip_prefix('"')
                        .and_then(|s| s.strip_suffix('"'))
                        .map(str::trim)
                        .filter(|s| !s.is_empty())
                        .ok_or_else(|| {
                            anyhow::anyhow!(
                                "line {}: expected [layer \"pattern\"]",
                                lineno + 1
                            )
                        })?;
                    builder.overrides.push((pat.to_string(), LayerSpec::default()));
                    section = Some(Some(builder.overrides.len() - 1));
                } else {
                    section = None; // unknown section: ignored, like QuantConfig::from_file
                }
                continue;
            }
            let Some(target) = section else { continue };
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
            let (k, v) = (k.trim(), v.trim());
            match target {
                None => builder
                    .base
                    .set(k, v)
                    .with_context(|| format!("line {}", lineno + 1))?,
                Some(i) => builder.overrides[i]
                    .1
                    .set_key(k, v)
                    .with_context(|| format!("line {}", lineno + 1))?,
            }
        }
        // a [layer] section with no keys resolves nothing — reject early
        for (pat, spec) in &builder.overrides {
            if *spec == LayerSpec::default() {
                bail!("[layer \"{pat}\"] section sets no keys");
            }
        }
        Ok(builder)
    }

    /// [`PlanBuilder::from_manifest_text`] over a file path.
    pub fn from_file(path: &Path) -> Result<PlanBuilder> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        PlanBuilder::from_manifest_text(&text)
            .with_context(|| format!("parse {}", path.display()))
    }

    /// Compile against a model's quantizable layer list. Build-time
    /// validation: the base bit width must be supported (even when set by
    /// direct struct construction) and every override pattern must match
    /// at least one layer.
    pub fn build(&self, layers: &[String]) -> Result<QuantPlan> {
        if layers.is_empty() {
            bail!("cannot build a plan for zero quantizable layers");
        }
        let mut matched = vec![false; self.overrides.len()];
        let mut assignments = Vec::with_capacity(layers.len());
        for layer in layers {
            let mut a = LayerAssignment::from_base(layer, &self.base)?;
            for (oi, (pat, spec)) in self.overrides.iter().enumerate() {
                if glob_match(pat, layer) {
                    a.apply(spec);
                    matched[oi] = true;
                }
            }
            a.validate_scenario()?;
            assignments.push(a);
        }
        for (oi, (pat, _)) in self.overrides.iter().enumerate() {
            if !matched[oi] {
                bail!(
                    "layer override '{pat}' matches none of the {} quantizable layers \
                     (e.g. '{}')",
                    layers.len(),
                    layers[0]
                );
            }
        }
        Ok(QuantPlan { base: self.base.clone(), assignments })
    }
}

/// A resolved, validated per-layer quantization plan — what
/// [`crate::coordinator::Pipeline::quantize`] consumes. Assignments are
/// in pipeline (forward) order, one per quantizable layer.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantPlan {
    /// pipeline-level knobs (LN tuning, recapture policy, calib/eval
    /// counts, thread budget) + the defaults the assignments resolved from
    pub base: QuantConfig,
    pub assignments: Vec<LayerAssignment>,
}

impl QuantPlan {
    /// Uniform plan: every layer gets `cfg`'s method/bits. This is the
    /// compilation the legacy `quantize_cfg` shim performs.
    pub fn uniform(cfg: &QuantConfig, layers: &[String]) -> Result<QuantPlan> {
        PlanBuilder::uniform(cfg).build(layers)
    }

    /// Construct a plan directly from already-resolved assignments — the
    /// planner's emission path (its greedy allocation produces one
    /// concrete `(method, bits)` per layer, no glob compilation step).
    /// Applies the same base-config validation as [`PlanBuilder::build`];
    /// the emitted plan round-trips through [`QuantPlan::to_manifest`]
    /// like any other.
    pub fn from_assignments(
        base: QuantConfig,
        assignments: Vec<LayerAssignment>,
    ) -> Result<QuantPlan> {
        if assignments.is_empty() {
            bail!("cannot build a plan with zero assignments");
        }
        base.bit_width().context("base config")?;
        for a in &assignments {
            a.validate_scenario()?;
        }
        Ok(QuantPlan { base, assignments })
    }

    /// The assignment for a concrete layer name, if the plan covers it.
    pub fn assignment_for(&self, layer: &str) -> Option<&LayerAssignment> {
        self.assignments.iter().find(|a| a.layer == layer)
    }

    /// When every layer shares one recipe, the equivalent flat config.
    pub fn uniform_config(&self) -> Option<QuantConfig> {
        let first = self.assignments.first()?;
        if self.assignments.iter().all(|a| a.same_recipe(first)) {
            Some(first.to_config(&self.base))
        } else {
            None
        }
    }

    /// Human label: the legacy config label for uniform plans,
    /// `plan[4x beacon-2-bit + 12x comq-4-bit]` for mixed ones.
    pub fn label(&self) -> String {
        if let Some(cfg) = self.uniform_config() {
            return cfg.label();
        }
        let mut counts: BTreeMap<String, usize> = BTreeMap::new();
        for a in &self.assignments {
            *counts.entry(a.tag()).or_insert(0) += 1;
        }
        let parts: Vec<String> =
            counts.iter().map(|(tag, n)| format!("{n}x {tag}")).collect();
        format!("plan[{}]", parts.join(" + "))
    }

    /// Nominal bits per weight, weighted by each layer's element count
    /// (`numel(layer name)` — e.g. `|w| store.get(w).numel()`).
    pub fn effective_bits<F: Fn(&str) -> usize>(&self, numel: F) -> f64 {
        let mut bits_sum = 0.0f64;
        let mut n_sum = 0usize;
        for a in &self.assignments {
            let n = numel(&a.layer);
            bits_sum += a.bits.0 * n as f64;
            n_sum += n;
        }
        if n_sum == 0 {
            0.0
        } else {
            bits_sum / n_sum as f64
        }
    }

    /// Serialize to the `key = value` manifest format. The emitted file
    /// is fully resolved — one `[layer "name"]` section per concrete
    /// layer — so [`QuantPlan::from_manifest`] reproduces this exact plan
    /// on the same model regardless of how it was originally built.
    pub fn to_manifest(&self) -> String {
        let mut s = String::new();
        s.push_str("# beacon-ptq quantization plan (QuantPlan::to_manifest)\n");
        s.push_str("[quant]\n");
        for (k, v) in self.base.to_kv() {
            let _ = writeln!(s, "{k} = {v}");
        }
        for a in &self.assignments {
            let _ = writeln!(s, "\n[layer \"{}\"]", a.layer);
            let _ = writeln!(s, "method = {}", a.method.name());
            let _ = writeln!(s, "bits = {}", a.bits.0);
            let _ = writeln!(s, "loops = {}", a.loops);
            let _ = writeln!(s, "ec = {}", a.error_correction);
            let _ = writeln!(s, "centering = {}", a.centering);
            let _ = writeln!(s, "damp = {}", a.gptq_damp);
            let _ = writeln!(s, "group_size = {}", a.group_size);
            let _ = writeln!(s, "asym = {}", a.asymmetric);
            let _ = writeln!(s, "outlier_k = {}", a.outlier_k);
        }
        s
    }

    /// Parse a manifest (or any plan-bearing config file) and compile it
    /// against `layers`. Round-trip identity:
    /// `QuantPlan::from_manifest(&plan.to_manifest(), layers) == plan`.
    pub fn from_manifest(text: &str, layers: &[String]) -> Result<QuantPlan> {
        PlanBuilder::from_manifest_text(text)?.build(layers)
    }

    /// [`QuantPlan::from_manifest`] over a file path.
    pub fn from_file(path: &Path, layers: &[String]) -> Result<QuantPlan> {
        PlanBuilder::from_file(path)?.build(layers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layers() -> Vec<String> {
        vec![
            "blocks.0.qkv.w".into(),
            "blocks.0.proj.w".into(),
            "blocks.0.fc1.w".into(),
            "blocks.0.fc2.w".into(),
            "blocks.1.qkv.w".into(),
            "blocks.1.proj.w".into(),
            "blocks.1.fc1.w".into(),
            "blocks.1.fc2.w".into(),
        ]
    }

    #[test]
    fn glob_basics() {
        assert!(glob_match("blocks.*.fc1.w", "blocks.3.fc1.w"));
        assert!(glob_match("*", "anything.at.all"));
        assert!(glob_match("blocks.?.fc?.w", "blocks.0.fc2.w"));
        assert!(glob_match("blocks.*", "blocks.11.qkv.w"));
        assert!(!glob_match("blocks.?.fc1.w", "blocks.10.fc1.w"));
        assert!(!glob_match("blocks.*.fc1.w", "blocks.3.fc2.w"));
        assert!(!glob_match("locks.*", "blocks.0.qkv.w"));
        assert!(glob_match("*.w", "head.w"));
        assert!(!glob_match("*.w", "head.b"));
        assert!(glob_match("head.w", "head.w"));
    }

    #[test]
    fn spec_parse_forms() {
        let s = LayerSpec::parse("comq:4").unwrap();
        assert_eq!(s.method, Some(Method::Comq));
        assert_eq!(s.bits.unwrap().0, 4.0);
        let s = LayerSpec::parse("beacon:8+centering+loops=6").unwrap();
        assert_eq!(s.method, Some(Method::Beacon));
        assert_eq!(s.bits.unwrap().0, 8.0);
        assert_eq!(s.loops, Some(6));
        assert_eq!(s.centering, Some(true));
        let s = LayerSpec::parse(":2+ec").unwrap();
        assert_eq!(s.method, None);
        assert_eq!(s.bits.unwrap().0, 2.0);
        assert_eq!(s.error_correction, Some(true));
        let s = LayerSpec::parse("rtn").unwrap();
        assert_eq!(s.method, Some(Method::Rtn));
        assert_eq!(s.bits, None);
        let s = LayerSpec::parse("gptq:3+damp=0.05+noec").unwrap();
        assert_eq!(s.gptq_damp, Some(0.05));
        assert_eq!(s.error_correction, Some(false));
    }

    #[test]
    fn spec_parse_rejects_garbage() {
        assert!(LayerSpec::parse("").is_err());
        assert!(LayerSpec::parse("awq:4").is_err());
        assert!(LayerSpec::parse("beacon:7.3").is_err());
        assert!(LayerSpec::parse("beacon:2+bogus").is_err());
        assert!(LayerSpec::parse("+ec").is_err());
    }

    #[test]
    fn build_uniform_covers_all_layers() {
        let cfg = QuantConfig::default();
        let plan = QuantPlan::uniform(&cfg, &layers()).unwrap();
        assert_eq!(plan.assignments.len(), layers().len());
        assert!(plan.uniform_config().is_some());
        assert_eq!(plan.label(), cfg.label());
        for a in &plan.assignments {
            assert_eq!(a.method, Method::Beacon);
            assert_eq!(a.bits.0, 2.0);
        }
    }

    #[test]
    fn last_match_wins_and_field_merge() {
        let plan = PlanBuilder::uniform(&QuantConfig::default())
            .override_layers("blocks.*", "comq:4")
            .unwrap()
            .override_layers("blocks.1.*", ":3")
            .unwrap()
            .override_layers("blocks.1.fc2.w", "rtn")
            .unwrap()
            .build(&layers())
            .unwrap();
        // untouched by later overrides
        let a = plan.assignment_for("blocks.0.qkv.w").unwrap();
        assert_eq!((a.method, a.bits.0), (Method::Comq, 4.0));
        // ":3" re-bits but keeps the comq method from the earlier match
        let a = plan.assignment_for("blocks.1.qkv.w").unwrap();
        assert_eq!((a.method, a.bits.0), (Method::Comq, 3.0));
        // "rtn" swaps method but keeps the 3-bit width from ":3"
        let a = plan.assignment_for("blocks.1.fc2.w").unwrap();
        assert_eq!((a.method, a.bits.0), (Method::Rtn, 3.0));
        assert!(plan.uniform_config().is_none());
        assert!(plan.label().starts_with("plan["), "{}", plan.label());
    }

    #[test]
    fn build_rejects_unmatched_pattern() {
        let e = PlanBuilder::uniform(&QuantConfig::default())
            .override_layers("head.w", "beacon:8")
            .unwrap()
            .build(&layers())
            .unwrap_err()
            .to_string();
        assert!(e.contains("head.w"), "{e}");
    }

    #[test]
    fn build_rejects_bad_base_bits() {
        // direct struct construction bypasses set() validation; the plan
        // build must catch it instead of panicking mid-run
        let cfg = QuantConfig { bits: 7.3, ..QuantConfig::default() };
        let e = QuantPlan::uniform(&cfg, &layers()).unwrap_err();
        let chain = format!("{e:#}");
        assert!(chain.contains("7.3"), "{chain}");
    }

    #[test]
    fn manifest_round_trip_mixed() {
        let plan = PlanBuilder::uniform(&QuantConfig::default())
            .override_layers("blocks.*.fc?.w", "comq:4+loops=5")
            .unwrap()
            .override_layers("blocks.1.qkv.w", "gptq:3+damp=0.02")
            .unwrap()
            .build(&layers())
            .unwrap();
        let text = plan.to_manifest();
        let back = QuantPlan::from_manifest(&text, &layers()).unwrap();
        assert_eq!(back, plan);
    }

    #[test]
    fn manifest_pattern_sections_compile() {
        let text = "\
[quant]
method = beacon
bits = 2
loops = 4

[layer \"blocks.*.fc1.w\"]
spec = comq:4

[layer \"blocks.1.*\"]
method = rtn
bits = 3
";
        let plan = QuantPlan::from_manifest(text, &layers()).unwrap();
        let a = plan.assignment_for("blocks.0.fc1.w").unwrap();
        assert_eq!((a.method, a.bits.0), (Method::Comq, 4.0));
        let a = plan.assignment_for("blocks.1.fc1.w").unwrap();
        assert_eq!((a.method, a.bits.0), (Method::Rtn, 3.0));
        let a = plan.assignment_for("blocks.0.qkv.w").unwrap();
        assert_eq!((a.method, a.bits.0), (Method::Beacon, 2.0));
    }

    #[test]
    fn manifest_rejects_empty_layer_section() {
        let text = "[quant]\nbits = 2\n\n[layer \"blocks.*\"]\n";
        assert!(PlanBuilder::from_manifest_text(text).is_err());
        let bad = "[layer blocks.*]\nspec = rtn\n";
        assert!(PlanBuilder::from_manifest_text(bad).is_err());
    }

    #[test]
    fn from_assignments_round_trips_and_validates() {
        let base = QuantConfig::default();
        let assignments: Vec<LayerAssignment> = layers()
            .iter()
            .enumerate()
            .map(|(i, l)| LayerAssignment {
                layer: l.clone(),
                method: if i % 2 == 0 { Method::Beacon } else { Method::Comq },
                bits: if i % 2 == 0 { BitWidth::B2 } else { BitWidth::B4 },
                loops: base.loops,
                error_correction: base.error_correction,
                centering: base.centering,
                gptq_damp: base.gptq_damp,
                group_size: base.group_size,
                asymmetric: base.asymmetric,
                outlier_k: base.outlier_k,
            })
            .collect();
        let plan = QuantPlan::from_assignments(base.clone(), assignments).unwrap();
        let back = QuantPlan::from_manifest(&plan.to_manifest(), &layers()).unwrap();
        assert_eq!(back, plan);
        assert!(QuantPlan::from_assignments(base, Vec::new()).is_err());
        let bad = QuantConfig { bits: 7.3, ..QuantConfig::default() };
        let a = plan.assignments.clone();
        assert!(QuantPlan::from_assignments(bad, a).is_err());
    }

    #[test]
    fn spec_parse_scenario_flags() {
        let s = LayerSpec::parse("beacon:3+g16+asym+k2").unwrap();
        assert_eq!(s.method, Some(Method::Beacon));
        assert_eq!(s.bits.unwrap().0, 3.0);
        assert_eq!(s.group_size, Some(16));
        assert_eq!(s.asymmetric, Some(true));
        assert_eq!(s.outlier_k, Some(2));
        // sym flips asym back off; g0 restores per-channel
        let s = LayerSpec::parse(":4+sym+g0+k0").unwrap();
        assert_eq!(s.asymmetric, Some(false));
        assert_eq!(s.group_size, Some(0));
        assert_eq!(s.outlier_k, Some(0));
        // key=value spellings are equivalent
        let s = LayerSpec::parse("rtn+group_size=32+outlier_k=1+asym").unwrap();
        assert_eq!(s.group_size, Some(32));
        assert_eq!(s.outlier_k, Some(1));
        // garbage still rejected
        assert!(LayerSpec::parse("beacon:2+g1").is_err(), "degenerate group");
        assert!(LayerSpec::parse("beacon:2+gx").is_err());
        assert!(LayerSpec::parse("beacon:2+kitten").is_err());
    }

    #[test]
    fn scenario_plan_round_trip_and_gptq_rejection() {
        let plan = PlanBuilder::uniform(&QuantConfig::default())
            .override_layers("blocks.*.qkv.w", "beacon:3+g16+asym+k2")
            .unwrap()
            .override_layers("blocks.*.fc?.w", "comq:4+g32")
            .unwrap()
            .build(&layers())
            .unwrap();
        let a = plan.assignment_for("blocks.0.qkv.w").unwrap();
        assert_eq!((a.group_size, a.asymmetric, a.outlier_k), (16, true, 2));
        assert_eq!(a.tag(), "beacon-3-bit+g16+asym+k2");
        let back = QuantPlan::from_manifest(&plan.to_manifest(), &layers()).unwrap();
        assert_eq!(back, plan);
        // gptq cannot take the grouped/outlier axes — fails at build time
        let e = PlanBuilder::uniform(&QuantConfig::default())
            .override_layers("blocks.*", "gptq:4+g16")
            .unwrap()
            .build(&layers())
            .unwrap_err()
            .to_string();
        assert!(e.contains("gptq"), "{e}");
        let base = QuantConfig::default();
        let mut a = plan.assignments.clone();
        a[0].method = Method::Gptq;
        a[0].outlier_k = 2;
        a[0].group_size = 0;
        assert!(QuantPlan::from_assignments(base, a).is_err());
    }

    #[test]
    fn effective_bits_weighted() {
        let plan = PlanBuilder::uniform(&QuantConfig::default())
            .override_layers("blocks.*.fc?.w", ":4")
            .unwrap()
            .build(&layers())
            .unwrap();
        // qkv/proj at 2 bits, fc1/fc2 at 4 bits; equal sizes → mean 3.0
        let eb = plan.effective_bits(|_| 100);
        assert!((eb - 3.0).abs() < 1e-12, "{eb}");
        // size-weighted: fc layers 3x larger → (2·2 + 4·2·3)/(2+6) = 3.5
        let eb = plan.effective_bits(|name| if name.contains(".fc") { 300 } else { 100 });
        assert!((eb - 3.5).abs() < 1e-12, "{eb}");
    }
}
