//! Householder QR (reduced) and Cholesky — the factorizations behind the
//! paper's memory-efficient form (§3) and the GPTQ baseline.
//!
//! For Beacon with error correction we need, given X̃ = U·R and the FP
//! calibration matrix X:  L = UᵀX and L̃ = R (both N×N). [`qr_factor`]
//! computes the Householder reflectors of X̃ in place and applies Qᵀ to X,
//! returning the two square factors without ever forming U (m×N) —
//! exactly the memory saving the paper claims.

use super::matrix::Matrix;

#[derive(Debug, Clone)]
pub struct QrFactors {
    /// R (N×N upper triangular): the paper's L̃.
    pub r: Matrix,
    /// UᵀX (N×N): the paper's L. Equals R when `x` aliases `xt`.
    pub l: Matrix,
}

/// Factor `xt = U R` (Householder, reduced) and return `L̃ = R`,
/// `L = UᵀX`. `xt` and `x` must be m×N with m ≥ N.
///
/// Works on column-major copies so the reflector builds and applications
/// stream contiguous memory (the row-major indexed version walked an
/// m-stride per element and was ~8× slower at m = 2176 — §Perf).
pub fn qr_factor(xt: &Matrix, x: &Matrix) -> QrFactors {
    assert_eq!(xt.rows, x.rows, "X and X̃ must share sample count");
    assert_eq!(xt.cols, x.cols, "X and X̃ must share width");
    let (m, n) = (xt.rows, xt.cols);
    assert!(m >= n, "QR requires m >= N (got {m} < {n})");

    // column-major working copies; a -> R (upper part), b -> QᵀX
    let mut a = xt.columns();
    let same = std::ptr::eq(xt, x) || xt.data == x.data;
    let mut b = if same { a.clone() } else { x.columns() };

    let mut v = vec![0.0f64; m]; // Householder vector scratch

    for k in 0..n {
        // build reflector from column k, rows k..m (contiguous slice)
        let colk = &a[k][k..];
        let normx = crate::linalg::matrix::dot(colk, colk).sqrt();
        if normx == 0.0 {
            continue; // zero column: skip reflector (R gets a zero diag)
        }
        let alpha = if a[k][k] >= 0.0 { -normx } else { normx };
        v[k..m].copy_from_slice(&a[k][k..]);
        v[k] -= alpha;
        let vk = &v[k..m];
        let vnorm2 = crate::linalg::matrix::dot(vk, vk);
        if vnorm2 == 0.0 {
            continue;
        }
        let beta = 2.0 / vnorm2;

        // apply (I - beta v vᵀ) to remaining columns of a
        for col in a.iter_mut().skip(k) {
            let tail = &mut col[k..];
            let s = beta * crate::linalg::matrix::dot(vk, tail);
            crate::linalg::matrix::axpy(-s, vk, tail);
        }
        // and to all columns of b (accumulating QᵀX)
        for col in b.iter_mut() {
            let tail = &mut col[k..];
            let s = beta * crate::linalg::matrix::dot(vk, tail);
            crate::linalg::matrix::axpy(-s, vk, tail);
        }
    }

    // R = upper triangle of a's first n rows; L = first n rows of b
    let mut r = Matrix::zeros(n, n);
    let mut l = Matrix::zeros(n, n);
    for j in 0..n {
        for i in 0..n {
            if j >= i {
                r[(i, j)] = a[j][i];
            }
            l[(i, j)] = b[j][i];
        }
    }
    QrFactors { r, l }
}

/// Lower Cholesky factor L with `a = L Lᵀ`. Panics if `a` is not positive
/// definite (callers damp their Hessians first).
pub fn cholesky_lower(a: &Matrix) -> Matrix {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                assert!(
                    s > 0.0,
                    "matrix not positive definite at pivot {i} (s = {s})"
                );
                l[(i, j)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    l
}

/// Invert a lower-triangular matrix by forward substitution.
pub fn invert_lower(l: &Matrix) -> Matrix {
    let n = l.rows;
    let mut inv = Matrix::zeros(n, n);
    for j in 0..n {
        inv[(j, j)] = 1.0 / l[(j, j)];
        for i in j + 1..n {
            let mut s = 0.0;
            for k in j..i {
                s += l[(i, k)] * inv[(k, j)];
            }
            inv[(i, j)] = -s / l[(i, i)];
        }
    }
    inv
}

/// Symmetric positive-definite inverse via Cholesky: a⁻¹ = L⁻ᵀ L⁻¹.
pub fn spd_inverse(a: &Matrix) -> Matrix {
    let l = cholesky_lower(a);
    let linv = invert_lower(&l);
    linv.transpose().matmul(&linv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{prop_check, Gen};

    fn random_tall(g: &mut Gen, m: usize, n: usize) -> Matrix {
        Matrix::from_vec(m, n, g.vec_normal(m * n, 1.0))
    }

    #[test]
    fn qr_reconstructs_norms() {
        // rotation invariance: ||R w|| == ||X w|| for any w
        prop_check(20, |g| {
            let (m, n) = (24, 6);
            let x = random_tall(g, m, n);
            let f = qr_factor(&x, &x);
            let w = g.vec_normal(n, 1.0);
            let xw = x.matvec(&w);
            let rw = f.r.matvec(&w);
            let a: f64 = xw.iter().map(|v| v * v).sum::<f64>().sqrt();
            let b: f64 = rw.iter().map(|v| v * v).sum::<f64>().sqrt();
            if (a - b).abs() > 1e-8 * a.max(1.0) {
                return Err(format!("norms differ: {a} vs {b}"));
            }
            Ok(())
        });
    }

    #[test]
    fn qr_r_upper_triangular() {
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(3) };
        let x = random_tall(&mut g, 20, 5);
        let f = qr_factor(&x, &x);
        for i in 0..5 {
            for j in 0..i {
                assert_eq!(f.r[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn qr_inner_products_preserved() {
        // ⟨Xw, Xq⟩ == ⟨Rw, Rq⟩ — the identity Beacon's reduction rests on
        prop_check(20, |g| {
            let (m, n) = (32, 8);
            let x = random_tall(g, m, n);
            let f = qr_factor(&x, &x);
            let w = g.vec_normal(n, 1.0);
            let q = g.vec_normal(n, 1.0);
            let lhs = crate::linalg::matrix::dot(&x.matvec(&w), &x.matvec(&q));
            let rhs = crate::linalg::matrix::dot(&f.r.matvec(&w), &f.r.matvec(&q));
            if (lhs - rhs).abs() > 1e-7 * lhs.abs().max(1.0) {
                return Err(format!("{lhs} vs {rhs}"));
            }
            Ok(())
        });
    }

    #[test]
    fn qr_ec_identity() {
        // ⟨Xw, X̃q⟩ == ⟨Lw, Rq⟩ with L = UᵀX (eq. 5 of the paper)
        prop_check(20, |g| {
            let (m, n) = (32, 6);
            let xt = random_tall(g, m, n);
            let mut x = xt.clone();
            for v in x.data.iter_mut() {
                *v += 0.05 * g.normal();
            }
            let f = qr_factor(&xt, &x);
            let w = g.vec_normal(n, 1.0);
            let q = g.vec_normal(n, 1.0);
            let lhs = crate::linalg::matrix::dot(&x.matvec(&w), &xt.matvec(&q));
            let rhs = crate::linalg::matrix::dot(&f.l.matvec(&w), &f.r.matvec(&q));
            if (lhs - rhs).abs() > 1e-7 * lhs.abs().max(1.0) {
                return Err(format!("{lhs} vs {rhs}"));
            }
            Ok(())
        });
    }

    #[test]
    fn cholesky_roundtrip() {
        prop_check(20, |g| {
            let n = 6;
            let b = random_tall(g, 12, n);
            let mut a = b.gram();
            for i in 0..n {
                a[(i, i)] += 0.5; // damp to SPD
            }
            let l = cholesky_lower(&a);
            let back = l.matmul(&l.transpose());
            if a.sub(&back).frob_norm() > 1e-8 * a.frob_norm() {
                return Err("LL^T != A".into());
            }
            Ok(())
        });
    }

    #[test]
    fn spd_inverse_correct() {
        prop_check(10, |g| {
            let n = 5;
            let b = random_tall(g, 15, n);
            let mut a = b.gram();
            for i in 0..n {
                a[(i, i)] += 1.0;
            }
            let inv = spd_inverse(&a);
            let ident = a.matmul(&inv);
            if ident.sub(&Matrix::eye(n)).frob_norm() > 1e-7 {
                return Err("A * A^-1 != I".into());
            }
            Ok(())
        });
    }

    #[test]
    fn invert_lower_correct() {
        let mut g = Gen { rng: crate::data::rng::SplitMix64::new(5) };
        let b = random_tall(&mut g, 12, 4);
        let mut a = b.gram();
        for i in 0..4 {
            a[(i, i)] += 1.0;
        }
        let l = cholesky_lower(&a);
        let li = invert_lower(&l);
        let ident = l.matmul(&li);
        assert!(ident.sub(&Matrix::eye(4)).frob_norm() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "positive definite")]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]);
        cholesky_lower(&a);
    }
}
