//! Native dense linear algebra substrate (f64): matrices, Householder QR,
//! Cholesky, triangular utilities. Replaces LAPACK on the quantization
//! path — the PJRT artifacts only carry model graphs, so factorizations
//! stay in Rust and stay profileable.

pub mod matrix;
pub mod packed_gemm;
pub mod qr;

pub use matrix::Matrix;
pub use packed_gemm::{
    expand_channel, expand_channel_f32, packed_dot, packed_gemm,
    packed_matvec, packed_matvec_threads, PackedCol,
};
pub use qr::{cholesky_lower, qr_factor, QrFactors};
