//! Row-major dense f64 matrix with the handful of operations the PTQ
//! pipeline needs. Not a general-purpose BLAS: clarity + cache-friendly
//! loops, with the hot paths (column gather, gram, matvec) shaped for the
//! quantizer's access patterns.

use std::fmt;

#[derive(Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    /// row-major storage, len = rows * cols
    pub data: Vec<f64>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)
    }
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[&[f64]]) -> Matrix {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c);
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Matrix {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Matrix {
        assert_eq!(data.len(), rows * cols);
        Matrix {
            rows,
            cols,
            data: data.iter().map(|v| f64::from(*v)).collect(),
        }
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|v| *v as f32).collect()
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// C = A * B (ikj loop order: streams B's rows, accumulates C's row).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let brow = other.row(k);
                let orow = out.row_mut(i);
                for j in 0..other.cols {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    /// self^T * self — the gram matrix used by GPTQ/COMQ (symmetric; only
    /// the upper triangle is computed then mirrored).
    pub fn gram(&self) -> Matrix {
        let n = self.cols;
        let mut g = Matrix::zeros(n, n);
        for r in 0..self.rows {
            let row = self.row(r);
            for i in 0..n {
                let a = row[i];
                if a == 0.0 {
                    continue;
                }
                let gi = g.row_mut(i);
                for (j, x) in row.iter().enumerate().skip(i) {
                    gi[j] += a * x;
                }
            }
        }
        for i in 0..n {
            for j in 0..i {
                g[(i, j)] = g[(j, i)];
            }
        }
        g
    }

    /// y = A * x
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows)
            .map(|i| dot(self.row(i), x))
            .collect()
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a - b)
                .collect(),
        }
    }

    /// All columns gathered into contiguous slices (column-major copy).
    /// The Beacon/COMQ sweeps walk columns; this converts O(N) strided
    /// loads per access into one contiguous slice per column.
    pub fn columns(&self) -> Vec<Vec<f64>> {
        let mut out = vec![Vec::with_capacity(self.rows); self.cols];
        for i in 0..self.rows {
            let row = self.row(i);
            for (j, v) in row.iter().enumerate() {
                out[j].push(*v);
            }
        }
        out
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;

    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-lane manual unroll: the autovectorizer reliably turns this into
    // SIMD adds; naive iter().zip() sums serialize on the fp dependency.
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

#[inline]
pub fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Matrix::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        let c = a.matmul(&Matrix::eye(3));
        assert_eq!(c.data, a.data);
    }

    #[test]
    fn gram_matches_explicit() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        let g = a.gram();
        let expect = a.transpose().matmul(&a);
        for (x, y) in g.data.iter().zip(&expect.data) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = Matrix::from_rows(&[&[1.0, -2.0], &[0.5, 4.0]]);
        let y = a.matvec(&[2.0, 3.0]);
        assert_eq!(y, vec![-4.0, 13.0]);
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose().data, a.data);
    }

    #[test]
    fn columns_gather() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let cols = a.columns();
        assert_eq!(cols[0], vec![1.0, 3.0]);
        assert_eq!(cols[1], vec![2.0, 4.0]);
    }

    #[test]
    fn dot_unrolled_matches_naive() {
        let a: Vec<f64> = (0..23).map(|i| i as f64 * 0.5).collect();
        let b: Vec<f64> = (0..23).map(|i| (i as f64).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-9);
    }

    #[test]
    fn f32_roundtrip() {
        let a = Matrix::from_f32(2, 2, &[1.5, 2.5, 3.5, 4.5]);
        assert_eq!(a.to_f32(), vec![1.5, 2.5, 3.5, 4.5]);
    }
}
