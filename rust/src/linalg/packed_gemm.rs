//! Fused unpack–dequant–GEMM over bit-packed weight channels: the
//! serving-time compute path that never materializes an f32 (or f64)
//! weight matrix.
//!
//! A packed channel arrives as a little-endian bit stream of
//! `bits`-bit indices plus a dequant LUT — one `2^bits` stride per
//! group, concatenated (`lut[g·2^bits + k] = scale_g·v(k) + offset_g`,
//! built by `quant::packing::dequant_luts`; the entries are the
//! *exact* f32 values `unpack_channel` would produce). The kernel
//! walks the stream one 64-bit word at a time through a [`BitCursor`],
//! expands each index through the current group's LUT stride (the base
//! advances by counter at group boundaries — no division per element),
//! substitutes exact sidecar values at outlier rows, and FMAs straight
//! into the output accumulators. Dense channels are the single-group,
//! no-outlier case and take the exact same code path.
//!
//! Determinism contract, matching the rest of the crate:
//!
//! * [`packed_dot`] replicates [`super::matrix::dot`]'s 4-lane
//!   accumulation order exactly, so a fused dot is **bit-identical** to
//!   `dot(&expanded, x)` where `expanded[i] = f64::from(lut[idx_i])` —
//!   i.e. to unpack-then-matvec on the LUT values.
//! * All channel fan-out goes through
//!   [`crate::util::pool::par_map_labeled`] with index-order gather, so
//!   results are bit-identical at any thread count.
//!
//! Memory contract: [`packed_gemm`] is blocked channel-at-a-time — each
//! channel's codes are expanded once into a per-call scratch of `n`
//! f64s (amortized over every batch row) and the scratch is the *only*
//! transient the kernel allocates. Peak extra heap is one channel, not
//! one weight matrix.

use super::matrix::{dot, Matrix};
use crate::util::pool;

/// One packed weight channel as the kernel consumes it: a borrowed view
/// of the bit-stream words plus the channel's dequant LUT — one
/// `2^bits` stride per group, concatenated group-major
/// (`lut.len() == ngroups << bits`), so any index the stream can
/// encode is in range for every group. Dense channels are the
/// single-group case (`group_size == 0`, no outliers, one stride).
#[derive(Debug, Clone, Copy)]
pub struct PackedCol<'a> {
    /// storage bits per element (2/3/4 for the supported grids)
    pub bits: u32,
    /// number of packed elements
    pub len: usize,
    /// rows per group; 0 = one group for the whole channel
    pub group_size: usize,
    /// outlier sidecar (row, exact value), rows strictly ascending;
    /// the bit stream carries an on-grid dummy at these rows and the
    /// kernel substitutes the sidecar value after the LUT read
    pub outliers: &'a [(u32, f32)],
    /// little-endian bit stream, `bits` bits per element
    pub words: &'a [u64],
    /// `lut[g·2^bits + k]` = dequantized f32 value of index `k` in
    /// group `g`
    pub lut: &'a [f32],
}

impl PackedCol<'_> {
    fn ngroups(&self) -> usize {
        if self.group_size == 0 || self.len == 0 {
            1
        } else {
            (self.len + self.group_size - 1) / self.group_size
        }
    }

    fn validate(&self) {
        debug_assert!(self.bits >= 1 && self.bits <= 16, "bits {}", self.bits);
        debug_assert_eq!(
            self.lut.len(),
            self.ngroups() << self.bits,
            "LUT size for {} groups",
            self.ngroups()
        );
        debug_assert!(
            self.words.len() * 64 >= self.len * self.bits as usize,
            "bit stream too short: {} words for {}x{} bits",
            self.words.len(),
            self.len,
            self.bits
        );
    }
}

/// Sequential reader over a packed index stream: pulls one 64-bit word
/// at a time and hands out `bits`-bit indices, merging across word
/// boundaries (3-bit elements straddle words every 64/gcd(3,64)
/// elements).
struct BitCursor<'a> {
    words: &'a [u64],
    bits: usize,
    mask: u64,
    /// bottom `have` bits are the next unconsumed stream bits
    acc: u64,
    have: usize,
    /// next word to pull
    wi: usize,
}

impl<'a> BitCursor<'a> {
    fn new(col: &PackedCol<'a>) -> BitCursor<'a> {
        let bits = col.bits as usize;
        BitCursor {
            words: col.words,
            bits,
            mask: (1u64 << bits) - 1,
            acc: 0,
            have: 0,
            wi: 0,
        }
    }

    /// The next index in the stream. Caller must not read past the
    /// element count the stream was packed with.
    #[inline]
    fn next_idx(&mut self) -> usize {
        if self.have < self.bits {
            // merge the tail of `acc` with the head of the next word
            let next = self.words[self.wi];
            self.wi += 1;
            let idx = (self.acc | (next << self.have)) & self.mask;
            let used = self.bits - self.have;
            self.acc = next >> used;
            self.have = 64 - used;
            idx as usize
        } else {
            let idx = self.acc & self.mask;
            self.acc >>= self.bits;
            self.have -= self.bits;
            idx as usize
        }
    }
}

/// Sequential reader over a packed channel's *values*: a [`BitCursor`]
/// composed with the per-group LUT walk and the outlier sidecar. The
/// group's LUT base advances by counter (no division per element), and
/// outlier rows substitute their exact value after the stream's dummy
/// code has been consumed — so the cursor always advances the bit
/// stream uniformly.
struct ValueCursor<'a> {
    cur: BitCursor<'a>,
    lut: &'a [f32],
    outliers: &'a [(u32, f32)],
    /// LUT stride per group (`1 << bits`)
    step: usize,
    /// rows per group (`usize::MAX` for single-group channels)
    group_size: usize,
    /// current group's LUT base offset
    base: usize,
    /// rows remaining in the current group
    left: usize,
    /// next unconsumed outlier record
    oi: usize,
    /// current row
    row: usize,
}

impl<'a> ValueCursor<'a> {
    fn new(col: &PackedCol<'a>) -> ValueCursor<'a> {
        let gs = if col.group_size == 0 {
            usize::MAX
        } else {
            col.group_size
        };
        ValueCursor {
            cur: BitCursor::new(col),
            lut: col.lut,
            outliers: col.outliers,
            step: 1usize << col.bits,
            group_size: gs,
            base: 0,
            left: gs,
            oi: 0,
            row: 0,
        }
    }

    /// The next dequantized value. For dense channels this is exactly
    /// the old single-LUT read, so the fused paths stay bit-identical.
    #[inline]
    fn next(&mut self) -> f32 {
        if self.left == 0 {
            self.base += self.step;
            self.left = self.group_size;
        }
        self.left -= 1;
        let idx = self.cur.next_idx();
        let v = self.lut[self.base + idx];
        self.row += 1;
        if self.oi < self.outliers.len()
            && self.outliers[self.oi].0 as usize == self.row - 1
        {
            let exact = self.outliers[self.oi].1;
            self.oi += 1;
            exact
        } else {
            v
        }
    }
}

/// Expand a packed channel into dequantized f64 values
/// (`out[i] = f64::from(lut[idx_i])`). `out.len()` must equal
/// `col.len`. This is the scalar reference twin of the fused paths —
/// and the block primitive [`packed_gemm`] amortizes over batch rows.
pub fn expand_channel(col: &PackedCol, out: &mut [f64]) {
    col.validate();
    assert_eq!(out.len(), col.len, "expand_channel length mismatch");
    let mut cur = ValueCursor::new(col);
    for o in out.iter_mut() {
        *o = f64::from(cur.next());
    }
}

/// [`expand_channel`] staying in f32 (`out[i] = lut[idx_i]`): the LUT
/// entries are exactly the f32 values `unpack_channel` produces, so
/// this materializes a channel of an f32 weight tensor straight from
/// the bit stream — the `eval --load-packed` path uses it to build
/// PJRT weight literals without an intermediate f64 matrix.
pub fn expand_channel_f32(col: &PackedCol, out: &mut [f32]) {
    col.validate();
    assert_eq!(out.len(), col.len, "expand_channel_f32 length mismatch");
    let mut cur = ValueCursor::new(col);
    for o in out.iter_mut() {
        *o = cur.next();
    }
}

/// Fused dot product of `x` with a packed channel: walks the bit
/// stream, expands through the LUT, and accumulates with exactly
/// [`dot`]'s 4-lane order — bit-identical to
/// `dot(&expanded, x)` without materializing `expanded`.
pub fn packed_dot(col: &PackedCol, x: &[f64]) -> f64 {
    col.validate();
    assert_eq!(x.len(), col.len, "packed_dot length mismatch");
    let n = col.len;
    let mut cur = ValueCursor::new(col);
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += f64::from(cur.next()) * x[i];
        s1 += f64::from(cur.next()) * x[i + 1];
        s2 += f64::from(cur.next()) * x[i + 2];
        s3 += f64::from(cur.next()) * x[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += f64::from(cur.next()) * x[i];
    }
    s
}

/// `y = Wᵀx` over packed channels (`y[j] = ⟨channel j, x⟩`), fully
/// fused — no weight values are ever materialized. Serial on the
/// channel axis; see [`packed_matvec_threads`] for the fanned form.
pub fn packed_matvec(cols: &[PackedCol], x: &[f64]) -> Vec<f64> {
    cols.iter().map(|c| packed_dot(c, x)).collect()
}

/// [`packed_matvec`] with the channel axis fanned over `threads`
/// workers; index-order gather keeps the output bit-identical to the
/// serial path at any thread count.
pub fn packed_matvec_threads(
    cols: &[PackedCol],
    x: &[f64],
    threads: usize,
) -> Vec<f64> {
    if threads <= 1 {
        return packed_matvec(cols, x);
    }
    pool::par_map_labeled("linalg.packed_matvec", cols.len(), threads, |j| {
        packed_dot(&cols[j], x)
    })
}

/// Batched fused GEMM: `out = X · W` where `X` is m×n (rows are
/// requests) and `W`'s n-element columns arrive packed. Blocked
/// channel-at-a-time: each channel is expanded once into a scratch of
/// `n` f64s and reused across all m rows, so the unpack cost is
/// amortized over the batch and the only transient allocation is one
/// channel — never a weight matrix. Row dots use [`dot`], so every
/// output element is bit-identical to unpack-then-`matmul`-by-dots;
/// the channel fan gathers in index order (thread-count invariant).
pub fn packed_gemm(cols: &[PackedCol], x: &Matrix, threads: usize) -> Matrix {
    let (m, n) = (x.rows, x.cols);
    let np = cols.len();
    for c in cols {
        assert_eq!(c.len, n, "packed_gemm: channel len != x.cols");
    }
    let columns: Vec<Vec<f64>> =
        pool::par_map_labeled("linalg.packed_gemm", np, threads.max(1), |j| {
            let mut scratch = vec![0.0f64; n];
            expand_channel(&cols[j], &mut scratch);
            (0..m).map(|r| dot(x.row(r), &scratch)).collect()
        });
    let mut out = Matrix::zeros(m, np);
    for (j, col) in columns.iter().enumerate() {
        for r in 0..m {
            out[(r, j)] = col[r];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::SplitMix64;
    use crate::quant::alphabet::{alphabet, BitWidth};
    use crate::quant::packing::{dequant_lut, try_pack_channel, PackedChannel};
    use crate::util::prop::Gen;

    /// Pack a pseudo-random channel of `n` alphabet values at `width`.
    fn packed_case(
        seed: u64,
        n: usize,
        width: BitWidth,
    ) -> (PackedChannel, Vec<f32>) {
        let alph = alphabet(width);
        let mut g = Gen { rng: SplitMix64::new(seed) };
        let codes: Vec<f64> = (0..n).map(|_| *g.pick(&alph)).collect();
        let scale = g.f64_in(0.05, 1.5);
        let offset = g.f64_in(-0.3, 0.3);
        let p = try_pack_channel(&codes, scale, offset, width).unwrap();
        let lut = dequant_lut(&p, width);
        (p, lut)
    }

    fn col<'a>(p: &'a PackedChannel, lut: &'a [f32]) -> PackedCol<'a> {
        PackedCol {
            bits: p.bits,
            len: p.len,
            group_size: p.group_size as usize,
            outliers: &p.outliers,
            words: &p.words,
            lut,
        }
    }

    #[test]
    fn expand_matches_unpack_channel_bitwise() {
        for (width, n) in [
            (BitWidth::B2, 70usize),
            (BitWidth::B3, 70),
            (BitWidth::B4, 70),
            (BitWidth::B258, 33),
            (BitWidth::B158, 5),
        ] {
            let (p, lut) = packed_case(11, n, width);
            let mut out = vec![0.0f64; n];
            expand_channel(&col(&p, &lut), &mut out);
            let reference =
                crate::quant::packing::unpack_channel(&p, width);
            for (i, (a, b)) in out.iter().zip(&reference).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    f64::from(*b).to_bits(),
                    "{width:?} elem {i}"
                );
            }
        }
    }

    #[test]
    fn expand_f32_matches_unpack_channel_bitwise() {
        for (width, n) in [
            (BitWidth::B2, 70usize),
            (BitWidth::B3, 129),
            (BitWidth::B4, 64),
        ] {
            let (p, lut) = packed_case(17, n, width);
            let mut out = vec![0.0f32; n];
            expand_channel_f32(&col(&p, &lut), &mut out);
            let reference =
                crate::quant::packing::unpack_channel(&p, width);
            for (i, (a, b)) in out.iter().zip(&reference).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{width:?} elem {i}");
            }
        }
    }

    #[test]
    fn packed_dot_bit_identical_to_dot_of_expansion() {
        for (width, n) in [
            (BitWidth::B2, 257usize), // ragged tail + odd length
            (BitWidth::B3, 129),
            (BitWidth::B4, 64),
        ] {
            let (p, lut) = packed_case(23, n, width);
            let pc = col(&p, &lut);
            let mut expanded = vec![0.0f64; n];
            expand_channel(&pc, &mut expanded);
            let mut g = Gen { rng: SplitMix64::new(5) };
            let x = g.vec_normal(n, 1.0);
            let fused = packed_dot(&pc, &x);
            let reference = dot(&expanded, &x);
            assert_eq!(
                fused.to_bits(),
                reference.to_bits(),
                "{width:?} n={n}"
            );
        }
    }

    #[test]
    fn matvec_thread_invariant_and_matches_reference() {
        let width = BitWidth::B2;
        let n = 96;
        let np = 17;
        let packed: Vec<(PackedChannel, Vec<f32>)> =
            (0..np).map(|j| packed_case(100 + j as u64, n, width)).collect();
        let cols: Vec<PackedCol> =
            packed.iter().map(|(p, lut)| col(p, lut)).collect();
        let mut g = Gen { rng: SplitMix64::new(9) };
        let x = g.vec_normal(n, 1.0);

        // reference: unpack every channel, dot per channel
        let want: Vec<f64> = cols
            .iter()
            .map(|c| {
                let mut e = vec![0.0f64; n];
                expand_channel(c, &mut e);
                dot(&e, &x)
            })
            .collect();

        let serial = packed_matvec(&cols, &x);
        let fanned = packed_matvec_threads(&cols, &x, 4);
        for j in 0..np {
            assert_eq!(serial[j].to_bits(), want[j].to_bits(), "serial {j}");
            assert_eq!(fanned[j].to_bits(), want[j].to_bits(), "t=4 {j}");
        }
    }

    #[test]
    fn gemm_matches_matmul_of_unpacked_weights() {
        let width = BitWidth::B4;
        let (m, n, np) = (7usize, 48usize, 13usize);
        let packed: Vec<(PackedChannel, Vec<f32>)> =
            (0..np).map(|j| packed_case(300 + j as u64, n, width)).collect();
        let cols: Vec<PackedCol> =
            packed.iter().map(|(p, lut)| col(p, lut)).collect();
        let mut g = Gen { rng: SplitMix64::new(77) };
        let x = Matrix::from_vec(m, n, g.vec_normal(m * n, 1.0));

        // reference: materialize W (n×np) and multiply
        let mut w = Matrix::zeros(n, np);
        for (j, c) in cols.iter().enumerate() {
            let mut e = vec![0.0f64; n];
            expand_channel(c, &mut e);
            for i in 0..n {
                w[(i, j)] = e[i];
            }
        }
        let want = x.matmul(&w);

        for threads in [1usize, 4] {
            let got = packed_gemm(&cols, &x, threads);
            assert_eq!((got.rows, got.cols), (m, np));
            for i in 0..m {
                for j in 0..np {
                    let (a, b) = (got[(i, j)], want[(i, j)]);
                    assert!(
                        (a - b).abs() <= 1e-12 * b.abs().max(1.0),
                        "t={threads} ({i},{j}): {a} vs {b}"
                    );
                }
            }
        }
        // and the two thread counts are bit-identical to each other
        let t1 = packed_gemm(&cols, &x, 1);
        let t4 = packed_gemm(&cols, &x, 4);
        for (a, b) in t1.data.iter().zip(&t4.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn gemm_single_row_equals_matvec() {
        let width = BitWidth::B3;
        let n = 70;
        let np = 5;
        let packed: Vec<(PackedChannel, Vec<f32>)> =
            (0..np).map(|j| packed_case(500 + j as u64, n, width)).collect();
        let cols: Vec<PackedCol> =
            packed.iter().map(|(p, lut)| col(p, lut)).collect();
        let mut g = Gen { rng: SplitMix64::new(3) };
        let xv = g.vec_normal(n, 1.0);
        let x = Matrix::from_vec(1, n, xv.clone());
        let gemm = packed_gemm(&cols, &x, 1);
        let mv = packed_matvec(&cols, &xv);
        for j in 0..np {
            assert_eq!(gemm[(0, j)].to_bits(), mv[j].to_bits(), "{j}");
        }
    }

    /// Pack a grouped channel (g16, ragged tail) with outlier rows.
    fn grouped_case(
        seed: u64,
        n: usize,
        width: BitWidth,
    ) -> (PackedChannel, Vec<f32>) {
        let lv = alphabet(width).len();
        let mut g = Gen { rng: SplitMix64::new(seed) };
        let codes: Vec<f64> =
            (0..n).map(|_| g.usize_in(0, lv - 1) as f64).collect();
        let ngroups = (n + 15) / 16;
        let groups: Vec<(f64, f64)> = (0..ngroups)
            .map(|_| (g.f64_in(0.05, 1.5), g.f64_in(-0.3, 0.3)))
            .collect();
        let outliers = [(3usize, 7.5f64), (n - 1, -4.25)];
        let p = crate::quant::packing::pack_channel_grouped(
            &codes, &groups, 16, &outliers, width,
        )
        .unwrap();
        let lut = crate::quant::packing::dequant_luts(&p, width);
        (p, lut)
    }

    #[test]
    fn grouped_expand_matches_unpack_channel_bitwise() {
        for (width, n) in [
            (BitWidth::B2, 70usize), // ragged 6-row tail group
            (BitWidth::B3, 129),
            (BitWidth::B4, 64), // exact group multiple
        ] {
            let (p, lut) = grouped_case(41, n, width);
            let pc = col(&p, &lut);
            let reference = crate::quant::packing::unpack_channel(&p, width);
            let mut out = vec![0.0f64; n];
            expand_channel(&pc, &mut out);
            for (i, (a, b)) in out.iter().zip(&reference).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    f64::from(*b).to_bits(),
                    "{width:?} elem {i}"
                );
            }
            let mut out32 = vec![0.0f32; n];
            expand_channel_f32(&pc, &mut out32);
            for (i, (a, b)) in out32.iter().zip(&reference).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{width:?} f32 elem {i}");
            }
            // outliers surfaced exactly
            assert_eq!(out32[3].to_bits(), 7.5f32.to_bits());
            assert_eq!(out32[n - 1].to_bits(), (-4.25f32).to_bits());
        }
    }

    #[test]
    fn grouped_packed_dot_bit_identical_to_dot_of_expansion() {
        for (width, n) in [
            (BitWidth::B2, 257usize), // tail chunk + ragged tail group
            (BitWidth::B3, 129),
            (BitWidth::B4, 64),
        ] {
            let (p, lut) = grouped_case(43, n, width);
            let pc = col(&p, &lut);
            let mut expanded = vec![0.0f64; n];
            expand_channel(&pc, &mut expanded);
            let mut g = Gen { rng: SplitMix64::new(8) };
            let x = g.vec_normal(n, 1.0);
            let fused = packed_dot(&pc, &x);
            let reference = dot(&expanded, &x);
            assert_eq!(fused.to_bits(), reference.to_bits(), "{width:?} n={n}");
        }
    }

    #[test]
    fn cursor_handles_word_straddles() {
        // 3-bit stream: element 21 straddles words 0/1 (bits 63..66)
        let width = BitWidth::B3;
        let alph = alphabet(width);
        let want: Vec<usize> = (0..130).map(|i| (i * 5 + 2) % 8).collect();
        let codes: Vec<f64> = want.iter().map(|&k| alph[k]).collect();
        let p = try_pack_channel(&codes, 1.0, 0.0, width).unwrap();
        let lut = dequant_lut(&p, width);
        let pc = col(&p, &lut);
        let mut cur = BitCursor::new(&pc);
        for (i, &k) in want.iter().enumerate() {
            assert_eq!(cur.next_idx(), k, "elem {i}");
        }
    }
}
