//! Integration tests over the full stack: PJRT runtime + AOT artifacts +
//! coordinator. These need `make artifacts`; they skip (with a notice)
//! when the bundle is missing so bare `cargo test` stays green.

use std::path::Path;

use beacon_ptq::config::{Method, QuantConfig};
use beacon_ptq::coordinator::{KernelBackend, Pipeline};
use beacon_ptq::linalg::qr_factor;
use beacon_ptq::quant::alphabet::{alphabet, BitWidth};
use beacon_ptq::quant::beacon::{beacon_layer_prefactored, beacon_objective, BeaconOpts};

fn pipeline() -> Option<Pipeline> {
    if !Path::new("artifacts/manifest__tiny-sim.json").exists() {
        eprintln!("skipping integration test: run `make artifacts` first");
        return None;
    }
    Some(Pipeline::from_artifacts("artifacts", "tiny-sim").expect("load artifacts"))
}

#[test]
fn fp_eval_through_pjrt() {
    let Some(mut pipe) = pipeline() else { return };
    let top1 = pipe.fp_top1().unwrap();
    // the bundled model trains to ~92% on the held-out split
    assert!(top1 > 0.85, "FP top-1 {top1} unexpectedly low");
    assert!(top1 <= 1.0);
}

#[test]
fn collect_acts_shapes_match_spec() {
    let Some(pipe) = pipeline() else { return };
    let store = pipe.weights_fp.clone();
    let (logits, acts) = pipe.collect_acts(&store).unwrap();
    let m = &pipe.artifacts.manifest;
    assert_eq!(logits.len(), m.calib_count * m.cfg.num_classes);
    assert_eq!(acts.len(), m.quantizable.len());
    let tokens = m.calib_count * m.cfg.tokens();
    for (i, a) in acts.iter().enumerate() {
        assert_eq!(a.rows, tokens, "layer {i}");
        assert!(a.data.iter().all(|v| v.is_finite()));
    }
    // qkv inputs are LayerNorm outputs: per-row mean ~ 0
    let qkv_in = &acts[0];
    let mean: f64 = qkv_in.row(0).iter().sum::<f64>() / qkv_in.cols as f64;
    assert!(mean.abs() < 0.2, "ln output mean {mean}");
}

#[test]
fn pjrt_kernel_matches_native_twin() {
    let Some(pipe) = pipeline() else { return };
    let store = pipe.weights_fp.clone();
    let (_, acts) = pipe.collect_acts(&store).unwrap();
    let lname = &pipe.artifacts.manifest.quantizable[1]; // proj: 64x64
    let w = store.matrix(lname);
    let x = &acts[1];
    let qc = QuantConfig { bits: 2.0, loops: 4, ..QuantConfig::default() };

    let lq_pjrt = pipe.beacon_layer(&qc, x, x, &w).unwrap();
    let f = qr_factor(x, x);
    let a = alphabet(BitWidth::B2);
    let lq_native = beacon_layer_prefactored(
        &f.l,
        &f.r,
        x,
        x,
        &w,
        &a,
        &BeaconOpts { loops: 4, centering: false, ..Default::default() },
    );

    // same tie-break contract: identical codes except at rare f32/f64
    // near-ties; objectives must agree channel-wise to 1e-3.
    let mut mismatched_channels = 0;
    for j in 0..w.cols {
        let qp: Vec<f64> = lq_pjrt.codes[j].clone();
        let qn: Vec<f64> = lq_native.codes[j].clone();
        if qp != qn {
            mismatched_channels += 1;
        }
        let wj = w.col(j);
        let op = beacon_objective(&f.l, &f.r, &wj, &qp);
        let on = beacon_objective(&f.l, &f.r, &wj, &qn);
        assert!(
            (op - on).abs() < 1e-3,
            "channel {j}: pjrt obj {op} vs native {on}"
        );
    }
    assert!(
        mismatched_channels <= w.cols / 8,
        "{mismatched_channels}/{} channels disagree — contract broken",
        w.cols
    );
}

#[test]
fn beacon_2bit_end_to_end_beats_rtn() {
    let Some(mut pipe) = pipeline() else { return };
    let eval_count = 1024; // subset for speed
    let rtn = pipe
        .quantize_cfg(&QuantConfig {
            method: Method::Rtn,
            bits: 1.58,
            eval_count,
            ..QuantConfig::default()
        })
        .unwrap();
    let beacon = pipe
        .quantize_cfg(&QuantConfig {
            method: Method::Beacon,
            bits: 1.58,
            loops: 6,
            error_correction: true,
            centering: true,
            eval_count,
            ..QuantConfig::default()
        })
        .unwrap();
    assert!(
        beacon.top1 > rtn.top1,
        "beacon {} should beat rtn {} at 1.58-bit",
        beacon.top1,
        rtn.top1
    );
    // and a usable model survives even at 1.58 bits (paper's headline)
    assert!(beacon.top1 > 0.75, "1.58-bit beacon top1 {}", beacon.top1);
}

#[test]
fn variants_are_monotone_at_2bit() {
    let Some(mut pipe) = pipeline() else { return };
    let eval_count = 2048;
    let mk = |ec: bool, cent: bool| QuantConfig {
        method: Method::Beacon,
        bits: 2.0,
        loops: 4,
        error_correction: ec,
        centering: cent,
        eval_count,
        ..QuantConfig::default()
    };
    let plain = pipe.quantize_cfg(&mk(false, false)).unwrap().top1;
    let full = pipe.quantize_cfg(&mk(true, true)).unwrap().top1;
    // EC + centering must help at 2-bit (paper Table 1 rows 1→3); allow
    // a small noise margin on the subset eval
    assert!(
        full + 0.005 >= plain,
        "ec+centering {full} worse than plain {plain}"
    );
}

#[test]
fn ln_tune_losses_decrease() {
    let Some(mut pipe) = pipeline() else { return };
    let qc = QuantConfig {
        method: Method::Beacon,
        bits: 2.0,
        loops: 2,
        ln_tune: true,
        ln_tune_steps: 12,
        eval_count: 256,
        ..QuantConfig::default()
    };
    let report = pipe.quantize_cfg(&qc).unwrap();
    let l = &report.ln_tune_losses;
    assert_eq!(l.len(), 12);
    assert!(
        l[l.len() - 1] < l[0],
        "LN tuning did not reduce the distill loss: {l:?}"
    );
}

#[test]
fn quantized_checkpoint_roundtrip() {
    let Some(mut pipe) = pipeline() else { return };
    let qc = QuantConfig {
        bits: 2.0,
        loops: 2,
        eval_count: 512,
        ..QuantConfig::default()
    };
    let (report, store) = pipe.quantize_cfg_with_weights(&qc).unwrap();
    let tmp = std::env::temp_dir().join("beacon_ptq_roundtrip.bin");
    store.save(&tmp).unwrap();
    let back = beacon_ptq::model::WeightStore::load(&tmp, pipe.cfg()).unwrap();
    let top1 = beacon_ptq::coordinator::eval::top1(&pipe, &back, 512).unwrap();
    assert!((top1 - report.top1).abs() < 1e-9, "{top1} vs {}", report.top1);
}

#[test]
fn per_layer_errors_reported_for_all_layers() {
    let Some(mut pipe) = pipeline() else { return };
    let qc = QuantConfig { bits: 3.0, loops: 2, eval_count: 256, ..QuantConfig::default() };
    let report = pipe.quantize_cfg(&qc).unwrap();
    assert_eq!(
        report.layers.len(),
        pipe.artifacts.manifest.quantizable.len()
    );
    for (name, e) in report.layer_errors() {
        assert!(e.is_finite() && e >= 0.0 && e < 1.0, "{name}: {e}");
    }
    // uniform 3-bit plan: the effective-bits summary is exactly 3
    assert!((report.effective_bits - 3.0).abs() < 1e-12, "{}", report.effective_bits);
}

#[test]
fn convergence_series_monotone() {
    let Some(mut pipe) = pipeline() else { return };
    let table = beacon_ptq::coordinator::experiments::convergence(&mut pipe, 6).unwrap();
    // every row's series (cells 1..) must be non-decreasing
    for row in &table.rows {
        let vals: Vec<f64> = row[1..].iter().map(|c| c.parse().unwrap()).collect();
        for w in vals.windows(2) {
            assert!(w[1] >= w[0] - 1e-9, "{row:?}");
        }
        // and the paper's plateau: K4 captures >90% of the K0->K6 gain
        let gain_total = vals[vals.len() - 1] - vals[0];
        let gain_k4 = vals[4.min(vals.len() - 1)] - vals[0];
        if gain_total > 1e-6 {
            assert!(gain_k4 / gain_total > 0.9, "{row:?}");
        }
    }
}

/// Second model geometry (d=128, depth 6): the config system + artifact
/// contract generalize beyond the default model. Skipped unless
/// small-sim artifacts were built (`python -m compile.aot --config small-sim`).
#[test]
fn small_sim_config_end_to_end() {
    if !Path::new("artifacts/manifest__small-sim.json").exists() {
        eprintln!("skipping: small-sim artifacts not built");
        return;
    }
    let mut pipe = Pipeline::from_artifacts("artifacts", "small-sim").unwrap();
    assert_eq!(pipe.cfg().d_model, 128);
    assert_eq!(pipe.cfg().depth, 6);
    let fp = pipe.fp_top1().unwrap();
    assert!(fp > 0.8, "small-sim FP top-1 {fp}");
    let report = pipe
        .quantize_cfg(&QuantConfig {
            bits: 2.0,
            loops: 4,
            error_correction: true,
            centering: true,
            eval_count: 512,
            ..QuantConfig::default()
        })
        .unwrap();
    assert_eq!(report.layers.len(), 24); // 6 blocks × 4 linears
    assert!(report.top1 > 0.6, "2-bit small-sim top-1 {}", report.top1);
}

#[test]
fn native_backend_full_run() {
    let Some(mut pipe) = pipeline() else { return };
    pipe.backend = KernelBackend::Native;
    let report = pipe
        .quantize_cfg(&QuantConfig {
            bits: 4.0,
            loops: 4,
            centering: true, // asymmetric variant
            ..QuantConfig::default()
        })
        .unwrap();
    // 4-bit Beacon keeps the model within a few percent of FP (the paper's
    // 4-bit row; Beacon's edge is at ultra-low bits, not here)
    assert!(
        report.accuracy_drop() < 3.0,
        "4-bit drop {:.2}%",
        report.accuracy_drop()
    );
}

#[test]
fn uniform_plan_matches_legacy_cfg_path_bit_identically() {
    let Some(mut pipe) = pipeline() else { return };
    let qc = QuantConfig {
        method: Method::Beacon,
        bits: 2.0,
        loops: 2,
        eval_count: 256,
        ..QuantConfig::default()
    };
    // legacy shim (compiles a uniform plan internally) …
    let (r_cfg, store_cfg) = pipe.quantize_cfg_with_weights(&qc).unwrap();
    // … vs an explicitly built uniform plan, at a different thread count
    let mut plan = pipe.uniform_plan(&qc).unwrap();
    plan.base.threads = 4;
    let (r_plan, store_plan) = pipe.quantize_with_weights(&plan).unwrap();
    assert_eq!(r_cfg.label, r_plan.label);
    for name in pipe.quantizable().to_vec() {
        assert_eq!(
            store_cfg.get(&name).data,
            store_plan.get(&name).data,
            "{name}: uniform plan diverged from legacy path"
        );
    }
    assert!((r_cfg.top1 - r_plan.top1).abs() < 1e-12);
}

#[test]
fn auto_plan_search_end_to_end() {
    let Some(mut pipe) = pipeline() else { return };
    let base = QuantConfig { bits: 2.0, loops: 2, eval_count: 256, ..QuantConfig::default() };
    let space = beacon_ptq::config::SearchSpace::parse(3.0, None, Some("2,3,4")).unwrap();
    let (plan, report) = pipe.auto_plan(&base, &space).unwrap();

    // the budget holds on the real layer sizes
    let eff = plan.effective_bits(|name| pipe.weights_fp.get(name).numel());
    assert!(eff <= 3.0 + 1e-9, "{eff}");
    assert!((eff - report.effective_bits).abs() < 1e-9);
    assert!(report.budget_utilization() <= 1.0 + 1e-9);

    // acceptance criterion: the searched plan ties-or-beats the uniform
    // plan at the budget width on the size-weighted probe objective over
    // the bundled calibration set
    let searched: f64 = report
        .layers
        .iter()
        .map(|lr| lr.numel as f64 * lr.chosen.error)
        .sum();
    let uniform: f64 = report
        .layers
        .iter()
        .map(|lr| {
            let c = lr
                .probes
                .iter()
                .filter(|c| (c.bits.0 - 3.0).abs() < 1e-9)
                .min_by(|a, b| a.error.total_cmp(&b.error))
                .expect("3-bit probe");
            lr.numel as f64 * c.error
        })
        .sum();
    assert!(searched <= uniform + 1e-9, "searched {searched} vs uniform-3 {uniform}");

    // manifest round-trip against the model, like --save-plan emits it
    let back = beacon_ptq::config::QuantPlan::from_manifest(
        &plan.to_manifest(),
        pipe.quantizable(),
    )
    .unwrap();
    assert_eq!(back, plan);

    // the search is bit-identical at another thread count
    let mut base4 = base.clone();
    base4.threads = 4;
    let (plan4, _) = pipe.auto_plan(&base4, &space).unwrap();
    assert_eq!(plan4.assignments, plan.assignments);

    // and the searched plan runs end-to-end
    let quant = pipe.quantize(&plan).unwrap();
    assert!(quant.top1 > 0.5, "searched plan top-1 {}", quant.top1);
}

#[test]
fn mixed_plan_end_to_end_with_manifest_round_trip() {
    let Some(mut pipe) = pipeline() else { return };
    // ≥ 2 methods and ≥ 2 bit widths across layers (acceptance criterion)
    let base = QuantConfig { bits: 2.0, loops: 2, eval_count: 512, ..QuantConfig::default() };
    let plan = beacon_ptq::config::PlanBuilder::uniform(&base)
        .override_layers("blocks.*.fc?.w", "comq:4+loops=2")
        .unwrap()
        .override_layers("blocks.0.proj.w", "rtn:3")
        .unwrap()
        .build(pipe.quantizable())
        .unwrap();
    assert!(plan.uniform_config().is_none(), "plan should be mixed");

    // manifest round-trip reproduces the exact plan …
    let text = plan.to_manifest();
    let back = beacon_ptq::config::QuantPlan::from_manifest(&text, pipe.quantizable()).unwrap();
    assert_eq!(back, plan);

    // … and the mixed plan runs end-to-end through Pipeline::quantize
    let report = pipe.quantize(&plan).unwrap();
    assert_eq!(report.layers.len(), pipe.quantizable().len());
    let fc = report
        .layers
        .iter()
        .find(|r| r.layer == "blocks.1.fc1.w")
        .unwrap();
    assert_eq!((fc.method, fc.bits.0), (Method::Comq, 4.0));
    let qkv = report.layers.iter().find(|r| r.layer == "blocks.1.qkv.w").unwrap();
    assert_eq!((qkv.method, qkv.bits.0), (Method::Beacon, 2.0));
    // effective bits lands strictly between the two widths
    assert!(
        report.effective_bits > 2.0 && report.effective_bits < 4.0,
        "{}",
        report.effective_bits
    );
    assert!(report.top1 > 0.5, "mixed plan top-1 {}", report.top1);
    assert!(report.label.starts_with("plan["), "{}", report.label);
}
