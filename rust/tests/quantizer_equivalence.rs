//! Equivalence properties of the `Quantizer` trait layer (no artifacts
//! needed — pure native kernels):
//!
//! 1. every `Quantizer` impl is bit-identical to the legacy free function
//!    it wraps (`beacon_layer` / `gptq_layer` / `rtn_layer` / `comq_layer`),
//! 2. the parallel scheduler matches the serial path bit-for-bit at
//!    `threads ∈ {1, 4}`, on both the channel axis and the layer axis.

use beacon_ptq::config::{Method, QuantConfig};
use beacon_ptq::data::rng::SplitMix64;
use beacon_ptq::linalg::Matrix;
use beacon_ptq::quant::alphabet::{alphabet, BitWidth};
use beacon_ptq::quant::beacon::{beacon_layer, BeaconOpts};
use beacon_ptq::quant::engine::{self, LayerCtx, LayerQuant, Quantizer};
use beacon_ptq::quant::{comq_layer, gptq_layer, rtn_layer};
use beacon_ptq::util::prop::Gen;

fn case(seed: u64, m: usize, n: usize, np: usize) -> (Matrix, Matrix) {
    let mut g = Gen { rng: SplitMix64::new(seed) };
    let x = Matrix::from_vec(m, n, g.vec_normal(m * n, 1.0));
    let w = Matrix::from_vec(n, np, g.vec_normal(n * np, 0.3));
    (x, w)
}

fn qc(method: Method, bits: f64, loops: usize) -> QuantConfig {
    QuantConfig { method, bits, loops, ..QuantConfig::default() }
}

/// Build the trait object as the plan/engine does: per-layer bit width
/// threaded through `Method::quantizer` explicitly.
fn quantizer_for(c: &QuantConfig) -> Box<dyn Quantizer> {
    c.method.quantizer(c.bit_width().unwrap(), c)
}

fn assert_layer_quant_eq(a: &LayerQuant, b: &LayerQuant, what: &str) {
    assert_eq!(a.codes, b.codes, "{what}: codes differ");
    assert_eq!(a.scales, b.scales, "{what}: scales differ");
    assert_eq!(a.offsets, b.offsets, "{what}: offsets differ");
    assert_eq!(a.dequant.data, b.dequant.data, "{what}: dequant differs");
}

#[test]
fn beacon_quantizer_matches_legacy_free_function() {
    for (seed, centering) in [(1u64, false), (2, true), (3, false)] {
        let (x, w) = case(seed, 48, 10, 6);
        let c = QuantConfig { centering, ..qc(Method::Beacon, 2.0, 3) };
        let lq = quantizer_for(&c)
            .quantize_layer(&LayerCtx::plain(&x, &w, 1))
            .unwrap();
        let legacy = beacon_layer(
            &x,
            &x,
            &w,
            &alphabet(BitWidth::B2),
            &BeaconOpts { loops: 3, centering, threads: 1 },
        );
        assert_layer_quant_eq(&lq, &legacy, &format!("seed {seed}"));
    }
}

#[test]
fn grid_quantizers_match_legacy_free_functions() {
    for seed in [4u64, 5] {
        let (x, w) = case(seed, 64, 12, 5);
        for bits in [BitWidth::B2, BitWidth::B3] {
            let rtn = quantizer_for(&qc(Method::Rtn, bits.0, 0))
                .quantize_layer(&LayerCtx::plain(&x, &w, 1))
                .unwrap();
            assert_eq!(
                rtn.dequant.data,
                rtn_layer(&w, bits).data,
                "rtn seed {seed}"
            );

            let gptq = quantizer_for(&qc(Method::Gptq, bits.0, 0))
                .quantize_layer(&LayerCtx::plain(&x, &w, 1))
                .unwrap();
            assert_eq!(
                gptq.dequant.data,
                gptq_layer(&x, &w, bits, 0.01).data,
                "gptq seed {seed}"
            );

            let comq = quantizer_for(&qc(Method::Comq, bits.0, 3))
                .quantize_layer(&LayerCtx::plain(&x, &w, 1))
                .unwrap();
            assert_eq!(
                comq.dequant.data,
                comq_layer(&x, &w, bits, 3).data,
                "comq seed {seed}"
            );
        }
    }
}

#[test]
fn channel_fanout_is_bit_identical_across_thread_counts() {
    let (x, w) = case(6, 64, 12, 8);
    for method in [Method::Beacon, Method::Gptq, Method::Rtn, Method::Comq] {
        let q = quantizer_for(&qc(method, 2.0, 3));
        let serial = q.quantize_layer(&LayerCtx::plain(&x, &w, 1)).unwrap();
        let par = q.quantize_layer(&LayerCtx::plain(&x, &w, 4)).unwrap();
        assert_layer_quant_eq(&par, &serial, method.name());
    }
}

#[test]
fn layer_scheduler_matches_serial_path() {
    // 5 independent "layers" of different shapes, as the non-EC pipeline
    // fans them: results must be bit-identical to the sequential loop at
    // threads ∈ {1, 4} and for every method.
    let layers: Vec<(Matrix, Matrix)> = vec![
        case(10, 48, 8, 5),
        case(11, 48, 8, 3),
        case(12, 40, 6, 6),
        case(13, 56, 10, 4),
        case(14, 48, 8, 5),
    ];
    for method in [Method::Beacon, Method::Rtn, Method::Comq, Method::Gptq] {
        let q = quantizer_for(&qc(method, 2.0, 2));
        let serial: Vec<LayerQuant> = layers
            .iter()
            .map(|(x, w)| q.quantize_layer(&LayerCtx::plain(x, w, 1)).unwrap())
            .collect();
        for threads in [1usize, 4] {
            let sched = engine::plan(threads, layers.len(), q.parallel_safe());
            let par: Vec<LayerQuant> =
                engine::run_layers(sched, layers.len(), |li| {
                    let (x, w) = &layers[li];
                    q.quantize_layer(&LayerCtx::plain(
                        x,
                        w,
                        sched.channel_threads,
                    ))
                })
                .unwrap();
            assert_eq!(par.len(), serial.len());
            for (li, (p, s)) in par.iter().zip(&serial).enumerate() {
                assert_layer_quant_eq(
                    p,
                    s,
                    &format!("{} layer {li} threads {threads}", method.name()),
                );
            }
        }
    }
}

#[test]
fn beacon_threads_env_parity_shape() {
    // The BEACON_THREADS env var flows through resolve_threads(0); an
    // explicit ctx budget must override nothing about the numbers — only
    // the wall clock. (Direct bitwise check at 2 and 4 workers.)
    let (x, w) = case(15, 80, 16, 12);
    let q = quantizer_for(&qc(Method::Beacon, 1.58, 4));
    let base = q.quantize_layer(&LayerCtx::plain(&x, &w, 1)).unwrap();
    for threads in [2usize, 4] {
        let other = q.quantize_layer(&LayerCtx::plain(&x, &w, threads)).unwrap();
        assert_layer_quant_eq(&other, &base, &format!("threads {threads}"));
    }
}
