//! Integration tests for the packed-weight runtime: real quantizer
//! output → `PackedStore` on disk (BPK1) → fused unpack-dequant kernel,
//! with the tracking allocator installed as the global allocator (it is
//! per-binary, so the lib unit tests cannot assert serving residency).
//!
//! 1. Beacon codes round-trip through BPK1 bit-identically and the file
//!    re-saves byte-identically,
//! 2. the fused `packed_matvec` matches unpack-then-matvec bit-for-bit
//!    at worker threads ∈ {1, 4},
//! 3. serving residency: packed store + dequant LUTs stay under the
//!    storage-bits ceiling vs materialized f32 channels (≤ 0.5× at
//!    4-bit, ≤ 0.3× at 2-bit),
//! 4. a corrupted checkpoint surfaces structured errors, never panics.
//!
//! Allocator counters are process-global, so every test serializes on
//! `lock()` like `memory_obs` does.

use std::sync::{Mutex, OnceLock};

use beacon_ptq::config::{Method, QuantConfig};
use beacon_ptq::data::rng::SplitMix64;
use beacon_ptq::linalg::{packed_matvec, packed_matvec_threads, Matrix};
use beacon_ptq::model::{PackedLayer, PackedStore};
use beacon_ptq::obs::{memory, TrackingAlloc};
use beacon_ptq::quant::alphabet::{alphabet, BitWidth};
use beacon_ptq::quant::engine::{LayerCtx, Quantizer as _};
use beacon_ptq::quant::packing::unpack_channel;
use beacon_ptq::util::prop::Gen;

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

fn lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("beacon_ptq_packed_runtime");
    std::fs::create_dir_all(&dir).expect("create temp dir");
    dir.join(name)
}

/// Quantize one synthetic layer with the real Beacon engine and pack
/// its codes. `m` calibration rows, channels of length `n`, `np`
/// channels (m ≥ n: the QR factor requires it).
fn quantized_layer(seed: u64, m: usize, n: usize, np: usize, width: BitWidth) -> PackedLayer {
    let mut g = Gen { rng: SplitMix64::new(seed) };
    let x = Matrix::from_vec(m, n, g.vec_normal(m * n, 1.0));
    let w = Matrix::from_vec(n, np, g.vec_normal(n * np, 0.3));
    let qc = QuantConfig { bits: width.0, loops: 2, ..QuantConfig::default() };
    let q = Method::Beacon.quantizer(width, &qc);
    let lq = q
        .quantize_layer(&LayerCtx::plain(&x, &w, 1))
        .expect("quantize layer");
    PackedLayer::pack("layer", &lq.codes, &lq.scales, &lq.offsets, width)
        .expect("beacon codes are on-grid")
}

#[test]
fn beacon_codes_roundtrip_bpk1_byte_identically() {
    let _g = lock();
    for (seed, width) in [(11u64, BitWidth::B2), (12, BitWidth::B3), (13, BitWidth::B4)] {
        let store = PackedStore {
            layers: vec![quantized_layer(seed, 80, 64, 24, width)],
        };
        let bits = width.storage_bits();
        let path = tmp(&format!("rt_{bits}.bpk"));
        store.save(&path).unwrap();
        let back = PackedStore::load(&path).unwrap();
        assert_eq!(back.layers.len(), 1);
        let (a, b) = (&store.layers[0], &back.layers[0]);
        assert_eq!(a.name, b.name, "{width:?}");
        assert_eq!(a.rows, b.rows, "{width:?}");
        assert_eq!(a.channels.len(), b.channels.len(), "{width:?}");
        for (j, (ca, cb)) in a.channels.iter().zip(&b.channels).enumerate() {
            let what = format!("{width:?} channel {j}");
            assert_eq!(ca.bits, cb.bits, "{what}");
            assert_eq!(ca.len, cb.len, "{what}");
            assert_eq!(ca.convention, cb.convention, "{what}");
            assert_eq!(ca.scale.to_bits(), cb.scale.to_bits(), "{what}");
            assert_eq!(ca.offset.to_bits(), cb.offset.to_bits(), "{what}");
            assert_eq!(ca.words, cb.words, "{what}");
        }
        // save → load → save reproduces the file byte-for-byte
        let path2 = tmp(&format!("rt_{bits}_resave.bpk"));
        back.save(&path2).unwrap();
        assert_eq!(
            std::fs::read(&path).unwrap(),
            std::fs::read(&path2).unwrap(),
            "{width:?}: resave not byte-identical"
        );
    }
}

#[test]
fn fused_matvec_bit_identical_to_unpack_then_matvec_across_threads() {
    let _g = lock();
    for (seed, width) in [(21u64, BitWidth::B2), (22, BitWidth::B4)] {
        let layer = quantized_layer(seed, 80, 64, 24, width);
        let luts = layer.luts();
        let cols = layer.kernel_cols(&luts);
        let mut g = Gen { rng: SplitMix64::new(seed ^ 0xA5A5) };
        let xv = g.vec_normal(layer.rows, 1.0);

        // reference: materialize every channel through unpack_channel
        // (the scalar twin) and run the dense matvec over the rows
        let dense: Vec<Vec<f64>> = layer
            .channels
            .iter()
            .map(|ch| unpack_channel(ch, width).iter().map(|&v| f64::from(v)).collect())
            .collect();
        let rows: Vec<&[f64]> = dense.iter().map(|r| r.as_slice()).collect();
        let want = Matrix::from_rows(&rows).matvec(&xv);

        let serial = packed_matvec(&cols, &xv);
        let threaded = packed_matvec_threads(&cols, &xv, 4);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&serial), bits(&want), "{width:?}: fused vs unpacked");
        assert_eq!(bits(&threaded), bits(&serial), "{width:?}: t=4 vs t=1");
    }
}

#[test]
fn packed_serving_residency_under_bits_ceiling() {
    let _g = lock();
    // long channels so per-channel struct overhead is noise (as in a
    // real layer); synthetic on-grid codes keep the test fast
    let (n, np) = (4096usize, 8usize);
    for (width, cap) in [(BitWidth::B4, 0.5), (BitWidth::B2, 0.3)] {
        let alph = alphabet(width);
        let codes: Vec<Vec<f64>> = (0..np)
            .map(|c| (0..n).map(|i| alph[(i + c) % alph.len()]).collect())
            .collect();
        let scales = vec![0.1f64; np];
        let offsets = vec![0.0f64; np];
        let layer =
            PackedLayer::pack("layer", &codes, &scales, &offsets, width).expect("on-grid");
        let store = PackedStore { layers: vec![layer] };
        let path = tmp(&format!("resident_{}.bpk", width.storage_bits()));
        store.save(&path).unwrap();
        drop(store);

        // f32 serving path: load, materialize every channel, drop the
        // packed form — resident is the dense channels
        let live0 = memory::reset_peak();
        let loaded = PackedStore::load(&path).unwrap();
        let f32_rows: Vec<Vec<f32>> = loaded.layers[0]
            .channels
            .iter()
            .map(|ch| unpack_channel(ch, width))
            .collect();
        drop(loaded);
        let f32_resident: u64 = f32_rows
            .iter()
            .map(|r| (r.len() * 4 + std::mem::size_of::<Vec<f32>>()) as u64)
            .sum();
        let f32_peak = memory::peak_bytes().saturating_sub(live0);
        drop(f32_rows);

        // packed serving path: load and build LUTs, nothing else
        let live1 = memory::reset_peak();
        let loaded = PackedStore::load(&path).unwrap();
        let luts = loaded.layers[0].luts();
        let lut_bytes: u64 = luts
            .iter()
            .map(|l| (l.len() * 4 + std::mem::size_of::<Vec<f32>>()) as u64)
            .sum();
        let packed_resident = loaded.resident_bytes() + lut_bytes;
        let packed_peak = memory::peak_bytes().saturating_sub(live1);
        drop(luts);
        drop(loaded);

        assert!(
            (packed_resident as f64) <= cap * f32_resident as f64,
            "{width:?}: packed resident {packed_resident} > {cap} × f32 {f32_resident}"
        );
        assert!(
            packed_peak <= f32_peak,
            "{width:?}: packed-path peak {packed_peak} > f32-path peak {f32_peak}"
        );
    }
}

#[test]
fn corrupted_checkpoint_is_structured_error_not_panic() {
    let _g = lock();
    let store = PackedStore {
        layers: vec![quantized_layer(31, 80, 64, 8, BitWidth::B4)],
    };
    let path = tmp("corrupt_base.bpk");
    store.save(&path).unwrap();
    let good = std::fs::read(&path).unwrap();

    let expect_err = |bytes: &[u8], what: &str, needle: &str| {
        let p = tmp("corrupt_case.bpk");
        std::fs::write(&p, bytes).unwrap();
        let err = PackedStore::load(&p).expect_err(what);
        let msg = format!("{err:#}");
        assert!(msg.contains(needle), "{what}: {msg:?} lacks {needle:?}");
    };

    let mut bad_magic = good.clone();
    bad_magic[0] = b'X';
    expect_err(&bad_magic, "bad magic", "magic");

    let mut future = good.clone();
    future[4..8].copy_from_slice(&99u32.to_le_bytes());
    expect_err(&future, "future version", "unsupported BPK1 version");

    expect_err(&good[..good.len() - 5], "truncated payload", "truncated");
    expect_err(&good[..10], "truncated header", "truncated");
}
