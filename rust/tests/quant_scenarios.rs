//! Integration tests for the quantization scenario axes (grouping,
//! asymmetry, outlier sidecars) across the full storage path: real
//! quantizer output → `PackedLayer::pack_quant` → BPK2 on disk → fused
//! unpack-dequant kernel.
//!
//! 1. grouped/asym/outlier layers round-trip through BPK2 byte-for-byte
//!    and the fused `packed_matvec` matches unpack-then-matvec
//!    bit-identically at worker threads ∈ {1, 4} — including a ragged
//!    tail group (channel length not a multiple of the group size),
//! 2. quantization itself is bit-identical at quantizer threads
//!    ∈ {1, 4} and outlier slots surface the exact weight,
//! 3. the default scenario (`g0`, sym, `k0`) packs byte-identically to
//!    the dense BPK1 path — old checkpoints and new ones agree,
//! 4. the acceptance recipe `beacon:3+g16+asym+k2` parses through the
//!    `--override` grammar and beats the dense symmetric plan at equal
//!    nominal bits on a layer with planted outliers.
//!
//! (BPK2 corruption → structured-error cases are unit-tested next to
//! the loader in `model::packed_store`.)

use beacon_ptq::config::{Method, PlanBuilder, QuantConfig};
use beacon_ptq::data::rng::SplitMix64;
use beacon_ptq::linalg::{packed_matvec, packed_matvec_threads, Matrix};
use beacon_ptq::model::{PackedLayer, PackedStore};
use beacon_ptq::quant::alphabet::BitWidth;
use beacon_ptq::quant::engine::{LayerCtx, LayerQuant, Quantizer as _};
use beacon_ptq::quant::packing::unpack_channel;
use beacon_ptq::util::prop::Gen;

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("beacon_ptq_quant_scenarios");
    std::fs::create_dir_all(&dir).expect("create temp dir");
    dir.join(name)
}

/// Synthetic calibration + weights; `n = 40` leaves a ragged 8-row tail
/// at group size 16. A few dominating outliers are planted so the
/// sidecar has real work to do.
fn case(seed: u64, m: usize, n: usize, np: usize) -> (Matrix, Matrix) {
    let mut g = Gen { rng: SplitMix64::new(seed) };
    let x = Matrix::from_vec(m, n, g.vec_normal(m * n, 1.0));
    let mut w = Matrix::from_vec(n, np, g.vec_normal(n * np, 0.3));
    for j in 0..np {
        let i = (5 + 3 * j) % n;
        w[(i, j)] = 12.0 + w[(i, j)].abs();
    }
    (x, w)
}

fn quantize(x: &Matrix, w: &Matrix, qc: &QuantConfig, threads: usize) -> LayerQuant {
    qc.method
        .quantizer(qc.bit_width().unwrap(), qc)
        .quantize_layer(&LayerCtx::plain(x, w, threads))
        .expect("quantize layer")
}

fn frob_err(w: &Matrix, dq: &Matrix) -> f64 {
    w.data
        .iter()
        .zip(&dq.data)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt()
}

#[test]
fn grouped_scenarios_roundtrip_bpk2_and_fused_kernel_bit_identical() {
    for (seed, method) in
        [(51u64, Method::Beacon), (52, Method::Rtn), (53, Method::Comq)]
    {
        let (x, w) = case(seed, 80, 40, 6);
        let qc = QuantConfig {
            method,
            bits: 3.0,
            loops: 2,
            group_size: 16,
            asymmetric: true,
            outlier_k: 2,
            ..QuantConfig::default()
        };
        let what = format!("{method:?}");
        let lq = quantize(&x, &w, &qc, 1);

        // quantization is bit-identical at 1 vs 4 quantizer threads
        let lq4 = quantize(&x, &w, &qc, 4);
        assert_eq!(lq.dequant.data, lq4.dequant.data, "{what}: t=4 dequant");
        assert_eq!(lq.codes, lq4.codes, "{what}: t=4 codes");

        // outlier slots carry the exact weight
        let meta = lq.grouped.as_ref().expect("non-dense scenario metadata");
        assert_eq!(meta.group_size, 16, "{what}");
        for (j, outl) in meta.outliers.iter().enumerate() {
            assert_eq!(outl.len(), 2, "{what}: channel {j} outlier count");
            for &(i, v) in outl {
                assert_eq!(v.to_bits(), w[(i, j)].to_bits(), "{what}: outlier ({i},{j})");
                assert_eq!(lq.dequant[(i, j)].to_bits(), w[(i, j)].to_bits(), "{what}");
            }
        }

        let width = BitWidth::B3;
        let layer = PackedLayer::pack_quant("layer", &lq, width).expect("on-grid codes");
        let store = PackedStore { layers: vec![layer] };
        let path = tmp(&format!("scenario_{}.bpk", what.to_lowercase()));
        store.save(&path).unwrap();

        // grouped checkpoints are BPK2 and re-save byte-identically
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..4], b"BPK2", "{what}");
        let back = PackedStore::load(&path).unwrap();
        let path2 = tmp(&format!("scenario_{}_resave.bpk", what.to_lowercase()));
        back.save(&path2).unwrap();
        assert_eq!(bytes, std::fs::read(&path2).unwrap(), "{what}: resave");

        // fused matvec over the loaded store ≡ unpack-then-matvec,
        // bit-for-bit, at kernel threads 1 and 4
        let loaded = &back.layers[0];
        let dense: Vec<Vec<f64>> = loaded
            .channels
            .iter()
            .map(|ch| unpack_channel(ch, width).iter().map(|&v| f64::from(v)).collect())
            .collect();
        let rows: Vec<&[f64]> = dense.iter().map(|r| r.as_slice()).collect();
        let mut g = Gen { rng: SplitMix64::new(seed ^ 0x5A5A) };
        let xv = g.vec_normal(loaded.rows, 1.0);
        let want = Matrix::from_rows(&rows).matvec(&xv);
        let luts = loaded.luts();
        let cols = loaded.kernel_cols(&luts);
        let serial = packed_matvec(&cols, &xv);
        let threaded = packed_matvec_threads(&cols, &xv, 4);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&serial), bits(&want), "{what}: fused vs unpacked");
        assert_eq!(bits(&threaded), bits(&serial), "{what}: kernel t=4 vs t=1");

        // the unpacked channels surface the outliers exactly (as f32)
        for (j, outl) in meta.outliers.iter().enumerate() {
            for &(i, v) in outl {
                assert_eq!(dense[j][i], v as f32 as f64, "{what}: unpacked outlier");
            }
        }
    }
}

#[test]
fn default_scenario_packs_byte_identical_to_dense_bpk1() {
    let (x, w) = case(61, 80, 40, 6);
    let qc = QuantConfig { bits: 3.0, loops: 2, ..QuantConfig::default() };
    let lq = quantize(&x, &w, &qc, 1);
    assert!(lq.grouped.is_none(), "default scenario must stay dense");

    let width = BitWidth::B3;
    let via_quant = PackedStore {
        layers: vec![PackedLayer::pack_quant("layer", &lq, width).expect("on-grid")],
    };
    let via_dense = PackedStore {
        layers: vec![
            PackedLayer::pack("layer", &lq.codes, &lq.scales, &lq.offsets, width)
                .expect("on-grid"),
        ],
    };
    let pa = tmp("default_quant.bpk");
    let pb = tmp("default_dense.bpk");
    via_quant.save(&pa).unwrap();
    via_dense.save(&pb).unwrap();
    let bytes = std::fs::read(&pa).unwrap();
    assert_eq!(&bytes[..4], b"BPK1", "dense stores keep the v1 container");
    assert_eq!(bytes, std::fs::read(&pb).unwrap(), "pack_quant vs legacy pack");
}

#[test]
fn override_grammar_recipe_beats_dense_at_equal_nominal_bits() {
    // the acceptance recipe, straight through the plan grammar
    let mut builder = PlanBuilder::uniform(&QuantConfig::default());
    builder.add_override("attn.*", "beacon:3+g16+asym+k2").unwrap();
    let layers = vec!["attn.qkv.w".to_string(), "mlp.fc1.w".to_string()];
    let plan = builder.build(&layers).unwrap();
    let a = plan
        .assignments
        .iter()
        .find(|a| a.layer == "attn.qkv.w")
        .unwrap();
    let qc = a.to_config(&plan.base);
    assert_eq!(qc.method, Method::Beacon);
    assert_eq!(qc.bits, 3.0);
    assert_eq!((qc.group_size, qc.asymmetric, qc.outlier_k), (16, true, 2));

    // grouped+asym+outliers ≤ dense symmetric error at the same
    // nominal bit width on the planted-outlier layer
    let (x, w) = case(71, 80, 40, 6);
    let scenario = quantize(&x, &w, &qc, 1);
    let dense_qc = QuantConfig { method: Method::Beacon, bits: 3.0, ..QuantConfig::default() };
    let dense = quantize(&x, &w, &dense_qc, 1);
    let (es, ed) = (frob_err(&w, &scenario.dequant), frob_err(&w, &dense.dequant));
    assert!(es <= ed, "scenario err {es} > dense err {ed}");
}
