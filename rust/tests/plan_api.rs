//! Plan-API properties that need no artifacts (pure native kernels):
//!
//! 1. a uniform `QuantPlan`'s per-layer quantizers are bit-identical to
//!    the flat-config quantizer the legacy path builds — the engine-level
//!    half of the `quantize_cfg ≡ quantize(uniform plan)` guarantee
//!    (the pipeline-level half runs in `pipeline_integration.rs`),
//! 2. override precedence composes with real model layer names
//!    (last match wins, field-wise merge),
//! 3. plan manifests round-trip, and rebuild identically against the
//!    model's layer list,
//! 4. build-time validation: zero-match patterns, malformed specs, and
//!    unsupported bit widths (including `QuantConfig { bits: 7.3, .. }`
//!    smuggled past `set()` by direct struct construction) all fail
//!    before any layer runs.

use beacon_ptq::config::{Method, PlanBuilder, QuantConfig, QuantPlan};
use beacon_ptq::data::rng::SplitMix64;
use beacon_ptq::linalg::Matrix;
use beacon_ptq::model::spec::{quantizable_layers, ViTConfig};
use beacon_ptq::quant::engine::{LayerCtx, Quantizer as _};
use beacon_ptq::util::prop::Gen;

fn layers() -> Vec<String> {
    quantizable_layers(&ViTConfig::tiny_sim())
}

fn case(seed: u64, m: usize, n: usize, np: usize) -> (Matrix, Matrix) {
    let mut g = Gen { rng: SplitMix64::new(seed) };
    let x = Matrix::from_vec(m, n, g.vec_normal(m * n, 1.0));
    let w = Matrix::from_vec(n, np, g.vec_normal(n * np, 0.3));
    (x, w)
}

#[test]
fn uniform_plan_quantizers_match_flat_config_bit_identically() {
    let (x, w) = case(21, 64, 12, 7);
    for method in [Method::Beacon, Method::Gptq, Method::Rtn, Method::Comq] {
        let qc = QuantConfig { method, bits: 2.0, loops: 3, ..QuantConfig::default() };
        let plan = QuantPlan::uniform(&qc, &layers()).unwrap();
        assert_eq!(plan.assignments.len(), layers().len());
        let legacy = method
            .quantizer(qc.bit_width().unwrap(), &qc)
            .quantize_layer(&LayerCtx::plain(&x, &w, 1))
            .unwrap();
        for a in &plan.assignments {
            let lq = a
                .quantizer(&plan.base)
                .quantize_layer(&LayerCtx::plain(&x, &w, 1))
                .unwrap();
            assert_eq!(lq.codes, legacy.codes, "{method:?} {}", a.layer);
            assert_eq!(lq.scales, legacy.scales, "{method:?} {}", a.layer);
            assert_eq!(lq.offsets, legacy.offsets, "{method:?} {}", a.layer);
            assert_eq!(lq.dequant.data, legacy.dequant.data, "{method:?} {}", a.layer);
        }
    }
}

#[test]
fn mixed_plan_assignments_use_their_own_method_and_bits() {
    let (x, w) = case(22, 64, 12, 6);
    let base = QuantConfig { bits: 2.0, loops: 3, ..QuantConfig::default() };
    let plan = PlanBuilder::uniform(&base)
        .override_layers("blocks.*.fc?.w", "comq:4")
        .unwrap()
        .build(&layers())
        .unwrap();
    // an fc assignment must reproduce the flat comq-4bit quantizer …
    let fc = plan.assignment_for("blocks.2.fc1.w").unwrap();
    let comq_cfg =
        QuantConfig { method: Method::Comq, bits: 4.0, loops: 3, ..QuantConfig::default() };
    let want = Method::Comq
        .quantizer(comq_cfg.bit_width().unwrap(), &comq_cfg)
        .quantize_layer(&LayerCtx::plain(&x, &w, 1))
        .unwrap();
    let got = fc
        .quantizer(&plan.base)
        .quantize_layer(&LayerCtx::plain(&x, &w, 1))
        .unwrap();
    assert_eq!(got.dequant.data, want.dequant.data);
    // … and a qkv assignment the base beacon-2bit quantizer
    let qkv = plan.assignment_for("blocks.2.qkv.w").unwrap();
    let want = Method::Beacon
        .quantizer(base.bit_width().unwrap(), &base)
        .quantize_layer(&LayerCtx::plain(&x, &w, 1))
        .unwrap();
    let got = qkv
        .quantizer(&plan.base)
        .quantize_layer(&LayerCtx::plain(&x, &w, 1))
        .unwrap();
    assert_eq!(got.dequant.data, want.dequant.data);
}

#[test]
fn override_precedence_on_model_layer_names() {
    let plan = PlanBuilder::uniform(&QuantConfig::default())
        .override_layers("blocks.*", "comq:4")
        .unwrap()
        .override_layers("blocks.3.*", "gptq:3+damp=0.02")
        .unwrap()
        .override_layers("blocks.3.fc2.w", ":2")
        .unwrap()
        .build(&layers())
        .unwrap();
    let a = plan.assignment_for("blocks.0.qkv.w").unwrap();
    assert_eq!((a.method, a.bits.0), (Method::Comq, 4.0));
    let a = plan.assignment_for("blocks.3.proj.w").unwrap();
    assert_eq!((a.method, a.bits.0, a.gptq_damp), (Method::Gptq, 3.0, 0.02));
    // ":2" re-bits only — method/damp survive from the earlier gptq match
    let a = plan.assignment_for("blocks.3.fc2.w").unwrap();
    assert_eq!((a.method, a.bits.0, a.gptq_damp), (Method::Gptq, 2.0, 0.02));
}

#[test]
fn manifest_round_trip_against_model_layers() {
    let plan = PlanBuilder::uniform(&QuantConfig {
        bits: 2.0,
        loops: 4,
        ln_tune: true,
        threads: 2,
        ..QuantConfig::default()
    })
    .override_layers("blocks.?.fc1.w", "comq:4+loops=6")
    .unwrap()
    .override_layers("blocks.2.*", "rtn:3")
    .unwrap()
    .build(&layers())
    .unwrap();
    let back = QuantPlan::from_manifest(&plan.to_manifest(), &layers()).unwrap();
    assert_eq!(back, plan);
    // the manifest also survives a disk round-trip
    let dir = std::env::temp_dir().join("beacon_ptq_plan_tests");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("mixed.cfg");
    std::fs::write(&p, plan.to_manifest()).unwrap();
    let back = QuantPlan::from_file(&p, &layers()).unwrap();
    assert_eq!(back, plan);
}

#[test]
fn build_time_validation_catches_bad_plans() {
    // pattern matching zero layers is rejected at build, naming the pattern
    let e = PlanBuilder::uniform(&QuantConfig::default())
        .override_layers("head.w", "beacon:8")
        .unwrap()
        .build(&layers())
        .unwrap_err()
        .to_string();
    assert!(e.contains("head.w"), "{e}");

    // malformed specs are rejected when the override is added
    let mut b = PlanBuilder::uniform(&QuantConfig::default());
    assert!(b.add_override("blocks.*", "awq:4").is_err());
    assert!(b.add_override("blocks.*", "beacon:7.3").is_err());
    assert!(b.add_override("", "beacon:2").is_err());

    // bits smuggled past set() by direct struct construction fail at
    // build time instead of panicking mid-run (the old bit_width() panic)
    let bad = QuantConfig { bits: 7.3, ..QuantConfig::default() };
    assert!(bad.bit_width().is_err());
    let e = QuantPlan::uniform(&bad, &layers()).unwrap_err();
    assert!(format!("{e:#}").contains("7.3"), "{e:#}");
}
