//! Integration tests for the batching server's scheduling behavior —
//! the properties the unit tests can't pin without real threads and
//! real clocks:
//!
//! 1. size-trigger flush: with an effectively infinite deadline every
//!    batch fills to exactly `max_batch`;
//! 2. deadline-trigger flush: with an effectively infinite `max_batch`
//!    and live clients, responses still arrive, in batches smaller than
//!    the size trigger — only the deadline can have flushed them;
//! 3. determinism: batched responses are bit-identical to the
//!    sequential single-request packed path at worker counts {1, 4};
//! 4. graceful drain: concurrent producers pushing through a
//!    near-capacity bounded queue lose nothing — every request id is
//!    answered exactly once and every output verifies.

use std::collections::BTreeSet;
use std::sync::Arc;
use std::time::Duration;

use beacon_ptq::data::rng::SplitMix64;
use beacon_ptq::quant::alphabet::BitWidth;
use beacon_ptq::serve::{
    synthetic_store, PackedModel, Response, ResponseHandle, ServeConfig,
    Server,
};
use beacon_ptq::util::prop::Gen;

fn model() -> Arc<PackedModel> {
    Arc::new(
        PackedModel::from_store(synthetic_store(2, 32, BitWidth::B4, 0xD14))
            .unwrap(),
    )
}

fn input(seed: u64, dim: usize) -> Vec<f64> {
    let mut g = Gen { rng: SplitMix64::new(seed) };
    g.vec_normal(dim, 1.0)
}

fn assert_bitwise(model: &PackedModel, x: &[f64], resp: &Response) {
    let want = model.forward_one(x, 1);
    assert_eq!(resp.output.len(), want.len());
    for (j, (a, b)) in resp.output.iter().zip(&want).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "request {} channel {j}: batched response diverged from the \
             sequential packed path",
            resp.id
        );
    }
}

#[test]
fn size_trigger_fills_every_batch_exactly() {
    let m = model();
    let (server, client) = Server::start(
        Arc::clone(&m),
        ServeConfig {
            max_batch: 4,
            // effectively never: only the size trigger can flush
            deadline: Duration::from_secs(10),
            workers: 1,
            threads: 1,
            ..ServeConfig::default()
        },
    );
    let xs: Vec<Vec<f64>> =
        (0..12).map(|r| input(0x512E ^ r as u64, m.input_dim())).collect();
    let handles: Vec<ResponseHandle> =
        xs.iter().map(|x| client.submit(x.clone())).collect();
    drop(client);
    for (x, h) in xs.iter().zip(handles) {
        let resp = h.wait();
        assert_eq!(resp.batch_size, 4, "only full batches should flush");
        assert_bitwise(&m, x, &resp);
    }
    let report = server.shutdown();
    assert_eq!(report.requests, 12);
    assert_eq!(report.batches, 3);
    assert_eq!(report.batch_sizes, vec![(4, 3)]);
}

#[test]
fn deadline_trigger_flushes_partial_batches() {
    let m = model();
    let (server, client) = Server::start(
        Arc::clone(&m),
        ServeConfig {
            // effectively never by size: only the deadline can flush
            max_batch: 64,
            deadline: Duration::from_millis(20),
            workers: 1,
            threads: 1,
            ..ServeConfig::default()
        },
    );
    let xs: Vec<Vec<f64>> =
        (0..3).map(|r| input(0xDEAD ^ r as u64, m.input_dim())).collect();
    let handles: Vec<ResponseHandle> =
        xs.iter().map(|x| client.submit(x.clone())).collect();
    // The client stays alive while we wait: if only disconnect-drain
    // flushed partial batches, these waits would hang forever.
    for (x, h) in xs.iter().zip(handles) {
        let resp = h.wait();
        assert!(
            resp.batch_size < 64,
            "batch of {} can only have flushed on deadline",
            resp.batch_size
        );
        assert_bitwise(&m, x, &resp);
    }
    drop(client);
    let report = server.shutdown();
    assert_eq!(report.requests, 3);
    assert!(report.batches >= 1 && report.batches <= 3);
    assert!(report.batch_sizes.iter().all(|&(size, _)| size < 64));
}

#[test]
fn batched_responses_bit_identical_across_worker_counts() {
    let m = model();
    for workers in [1usize, 4] {
        let (server, client) = Server::start(
            Arc::clone(&m),
            ServeConfig {
                max_batch: 4,
                deadline: Duration::from_millis(1),
                workers,
                threads: 4,
                ..ServeConfig::default()
            },
        );
        let xs: Vec<Vec<f64>> = (0..24)
            .map(|r| input(0xB17 ^ r as u64, m.input_dim()))
            .collect();
        let handles: Vec<ResponseHandle> =
            xs.iter().map(|x| client.submit(x.clone())).collect();
        drop(client);
        for (x, h) in xs.iter().zip(handles) {
            assert_bitwise(&m, x, &h.wait());
        }
        let report = server.shutdown();
        assert_eq!(report.workers, workers, "engine::plan honored the ask");
        assert_eq!(report.requests, 24);
    }
}

#[test]
fn graceful_drain_answers_every_request_exactly_once() {
    let m = model();
    const PRODUCERS: usize = 8;
    const PER_PRODUCER: usize = 25;
    let (server, client) = Server::start(
        Arc::clone(&m),
        ServeConfig {
            max_batch: 4,
            deadline: Duration::from_millis(1),
            workers: 2,
            threads: 2,
            // tiny bound: producers hit backpressure constantly
            queue_capacity: 4,
            ..ServeConfig::default()
        },
    );
    let joins: Vec<_> = (0..PRODUCERS)
        .map(|p| {
            let client = client.clone();
            let m = Arc::clone(&m);
            std::thread::spawn(move || {
                let mut got = Vec::with_capacity(PER_PRODUCER);
                for i in 0..PER_PRODUCER {
                    let x = input(
                        0xD12A ^ ((p as u64) << 32) ^ i as u64,
                        m.input_dim(),
                    );
                    // blocking submit: stalls while the queue is full
                    let h = client.submit(x.clone());
                    got.push((x, h));
                }
                got.into_iter()
                    .map(|(x, h)| (x, h.wait()))
                    .collect::<Vec<_>>()
            })
        })
        .collect();
    drop(client);

    let mut ids = BTreeSet::new();
    let mut total = 0usize;
    for j in joins {
        for (x, resp) in j.join().expect("producer thread panicked") {
            assert_bitwise(&m, &x, &resp);
            assert!(ids.insert(resp.id), "id {} answered twice", resp.id);
            total += 1;
        }
    }
    let expected = (PRODUCERS * PER_PRODUCER) as u64;
    assert_eq!(total as u64, expected, "a request was dropped");
    // ids are a dense 0..N: nothing was skipped or duplicated
    assert_eq!(ids.iter().next(), Some(&0));
    assert_eq!(ids.iter().next_back(), Some(&(expected - 1)));

    let report = server.shutdown();
    assert_eq!(report.requests, expected);
    let counted: u64 =
        report.batch_sizes.iter().map(|&(s, c)| s as u64 * c).sum();
    assert_eq!(counted, expected);
}
