//! Planner properties that need no artifacts (pure native kernels over
//! synthetic layers with the real tiny-sim layer names):
//!
//! 1. the searched plan is bit-identical at any thread count (probes fan
//!    through the engine scheduler with index-order gather),
//! 2. allocation is monotone in the budget: a larger budget never
//!    decreases any layer's width (prefix semantics over a
//!    budget-independent upgrade sequence),
//! 3. the size-weighted effective bits never exceed the budget,
//! 4. a budget at the floor (resp. top) candidate width degenerates to
//!    the uniform plan at that width, as does a single-width ladder,
//! 5. with equal-size layers and unit step costs, greedy beats (or ties)
//!    the uniform plan at the same effective bits on the probe
//!    objective — the classic exchange argument: the k-th greedy pick
//!    has gain ≥ the k-th largest uniform first-step gain,
//! 6. the searched plan round-trips through the manifest machinery.

use beacon_ptq::config::{Method, QuantConfig, QuantPlan, SearchSpace};
use beacon_ptq::coordinator::planner::{search_plan, LayerProbe, PlannerReport};
use beacon_ptq::data::rng::SplitMix64;
use beacon_ptq::linalg::Matrix;
use beacon_ptq::model::spec::{quantizable_layers, ViTConfig};
use beacon_ptq::util::prop::Gen;

/// Synthetic per-layer calibration data over the tiny-sim layer list.
/// `uniform_shape` forces every layer to the same geometry (the
/// equal-size precondition of the beats-uniform exchange argument).
struct Fixture {
    names: Vec<String>,
    xs: Vec<Matrix>,
    grams: Vec<Matrix>,
    ws: Vec<Matrix>,
}

impl Fixture {
    fn new(seed: u64, uniform_shape: bool) -> Fixture {
        let names = quantizable_layers(&ViTConfig::tiny_sim());
        let mut g = Gen { rng: SplitMix64::new(seed) };
        let m = 96;
        let mut xs = Vec::new();
        let mut ws = Vec::new();
        for (i, name) in names.iter().enumerate() {
            let (n, np) = if uniform_shape {
                (12, 10)
            } else if name.contains("qkv") {
                (12, 36)
            } else if name.contains("fc1") {
                (12, 24)
            } else if name.contains("fc2") {
                (24, 12)
            } else {
                (12, 12)
            };
            xs.push(Matrix::from_vec(m, n, g.vec_normal(m * n, 1.0)));
            let mut w = Matrix::from_vec(n, np, g.vec_normal(n * np, 0.3));
            if i % 3 == 0 {
                // outlier-heavy layers: harder at low bits, so the
                // allocation has real structure to find
                for (k, v) in w.data.iter_mut().enumerate() {
                    if k % 23 == 0 {
                        *v *= 5.0;
                    }
                }
            }
            ws.push(w);
        }
        let grams = xs.iter().map(|x| x.gram()).collect();
        Fixture { names, xs, grams, ws }
    }

    fn probes(&self) -> Vec<LayerProbe<'_>> {
        self.names
            .iter()
            .enumerate()
            .map(|(i, name)| LayerProbe {
                name: name.as_str(),
                x: &self.xs[i],
                gram: &self.grams[i],
                w: &self.ws[i],
                numel: self.ws[i].rows * self.ws[i].cols,
            })
            .collect()
    }

    fn numel(&self, i: usize) -> usize {
        self.ws[i].rows * self.ws[i].cols
    }
}

fn base_cfg(threads: usize) -> QuantConfig {
    // RTN probes: cheapest method, full planner machinery
    QuantConfig { method: Method::Rtn, bits: 2.0, threads, ..QuantConfig::default() }
}

/// Size-weighted probe error of a searched report's chosen cells.
fn weighted_chosen_error(fx: &Fixture, report: &PlannerReport) -> f64 {
    report
        .layers
        .iter()
        .enumerate()
        .map(|(i, lr)| fx.numel(i) as f64 * lr.chosen.error)
        .sum()
}

#[test]
fn searched_plan_is_thread_count_invariant() {
    let fx = Fixture::new(7, false);
    let probes = fx.probes();
    let space = SearchSpace::parse(2.58, None, None).unwrap();
    let (plan1, report1) = search_plan(&base_cfg(1), &probes, &space).unwrap();
    let (plan4, report4) = search_plan(&base_cfg(4), &probes, &space).unwrap();
    // thread count rides through plan.base — compare the allocation
    assert_eq!(plan1.assignments, plan4.assignments);
    for (a, b) in report1.layers.iter().zip(&report4.layers) {
        assert_eq!(a.probes.len(), b.probes.len());
        for (ca, cb) in a.probes.iter().zip(&b.probes) {
            assert_eq!(
                ca.error.to_bits(),
                cb.error.to_bits(),
                "{}: probe error diverged across thread counts",
                a.layer
            );
        }
    }
}

#[test]
fn allocation_is_monotone_in_budget_and_respects_it() {
    let fx = Fixture::new(11, false);
    let probes = fx.probes();
    let base = base_cfg(0);
    let budgets = [1.58, 2.0, 2.3, 2.58, 2.9, 3.0, 3.4, 4.0];
    let mut prev: Option<QuantPlan> = None;
    for b in budgets {
        let space = SearchSpace::new(b);
        let (plan, report) = search_plan(&base, &probes, &space).unwrap();
        assert!(
            report.effective_bits <= b + 1e-6,
            "budget {b}: effective {}",
            report.effective_bits
        );
        let eff = plan.effective_bits(|name| {
            let i = fx.names.iter().position(|n| n == name).unwrap();
            fx.numel(i)
        });
        assert!((eff - report.effective_bits).abs() < 1e-9);
        if let Some(p) = &prev {
            for (a, pa) in plan.assignments.iter().zip(&p.assignments) {
                assert!(
                    a.bits.0 >= pa.bits.0,
                    "budget {b}: layer {} width decreased ({} -> {})",
                    a.layer,
                    pa.bits.0,
                    a.bits.0
                );
            }
        }
        prev = Some(plan);
    }
}

#[test]
fn floor_top_and_single_width_budgets_are_uniform() {
    let fx = Fixture::new(13, false);
    let probes = fx.probes();
    let base = base_cfg(0);
    // floor of the default ladder
    let (plan, _) = search_plan(&base, &probes, &SearchSpace::new(1.58)).unwrap();
    assert!(plan.assignments.iter().all(|a| (a.bits.0 - 1.58).abs() < 1e-9));
    // top of the default ladder
    let (plan, report) = search_plan(&base, &probes, &SearchSpace::new(4.0)).unwrap();
    assert!(plan.assignments.iter().all(|a| (a.bits.0 - 4.0).abs() < 1e-9));
    assert!((report.effective_bits - 4.0).abs() < 1e-9);
    assert_eq!(report.upgrades_applied, report.upgrades_total);
    // single-width ladder equal to the budget
    let space = SearchSpace::parse(3.0, None, Some("3")).unwrap();
    let (plan, report) = search_plan(&base, &probes, &space).unwrap();
    assert!(plan.assignments.iter().all(|a| (a.bits.0 - 3.0).abs() < 1e-9));
    assert!((report.effective_bits - 3.0).abs() < 1e-9);
    assert!(plan.uniform_config().is_some(), "{}", plan.label());
}

#[test]
fn beats_uniform_at_equal_effective_bits_on_the_probe_objective() {
    // equal-size layers + integer widths {2,3,4} + budget 3.0: every
    // upgrade costs exactly 1/16 effective bit, so greedy applies
    // exactly 16 upgrades (effective bits land on 3.0 exactly) and the
    // exchange argument guarantees it ties-or-beats the uniform 3-bit
    // plan on the size-weighted probe error
    let fx = Fixture::new(17, true);
    let probes = fx.probes();
    let base = base_cfg(0);
    let space = SearchSpace::parse(3.0, None, Some("2,3,4")).unwrap();
    let (plan, report) = search_plan(&base, &probes, &space).unwrap();
    assert!((report.effective_bits - 3.0).abs() < 1e-9, "{}", report.effective_bits);
    let searched = weighted_chosen_error(&fx, &report);
    // uniform 3-bit error straight from the probe matrix
    let uniform: f64 = report
        .layers
        .iter()
        .enumerate()
        .map(|(i, lr)| {
            let cell = lr
                .probes
                .iter()
                .find(|c| (c.bits.0 - 3.0).abs() < 1e-9)
                .expect("3-bit probe");
            fx.numel(i) as f64 * cell.error
        })
        .sum();
    assert!(
        searched <= uniform + 1e-9,
        "searched {searched} worse than uniform-3 {uniform}"
    );
    assert_eq!(plan.assignments.len(), 16);
}

#[test]
fn searched_plan_round_trips_through_the_manifest() {
    let fx = Fixture::new(19, false);
    let probes = fx.probes();
    let space = SearchSpace::parse(2.58, Some("rtn,comq"), Some("2,3,4")).unwrap();
    let (plan, report) = search_plan(&base_cfg(0), &probes, &space).unwrap();
    // 2 methods × 3 widths × 16 layers probed
    assert_eq!(report.probe_count, 2 * 3 * 16);
    let text = plan.to_manifest();
    let back = QuantPlan::from_manifest(&text, &fx.names).unwrap();
    assert_eq!(back, plan);
    // and through a file, like --save-plan emits it
    let dir = std::env::temp_dir().join("beacon_ptq_planner_tests");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("searched.cfg");
    std::fs::write(&p, &text).unwrap();
    let back = QuantPlan::from_file(&p, &fx.names).unwrap();
    assert_eq!(back, plan);
}
