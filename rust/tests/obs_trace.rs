//! Integration tests for the observability recorder against the real
//! layer/channel scheduler:
//!
//! 1. spans nest across worker threads (≥ 2 distinct tids, each layer
//!    span time-contained in a worker span on its own thread),
//! 2. the disabled path records nothing at all,
//! 3. the emitted Chrome trace JSON round-trips through the repo's own
//!    `util::json` parser,
//! 4. quantization outputs are bit-identical with tracing on vs off at
//!    `threads ∈ {1, 4}` — recording never perturbs the numerics.
//!
//! The recorder is process-global, so every test takes `lock()` and
//! resets state on entry.

use std::sync::{Mutex, OnceLock};

use beacon_ptq::config::QuantConfig;
// Debug runs of this suite route every allocation through the tracking
// allocator, proving the recorder itself survives being metered (the
// bit-identity test then covers traced-vs-untraced under tracking too).
#[cfg(debug_assertions)]
use beacon_ptq::obs::TrackingAlloc;
use beacon_ptq::data::rng::SplitMix64;
use beacon_ptq::linalg::Matrix;
use beacon_ptq::obs;
use beacon_ptq::quant::engine::{self, LayerCtx, LayerQuant, Quantizer as _};
use beacon_ptq::util::json::Value;
use beacon_ptq::util::prop::Gen;

#[cfg(debug_assertions)]
#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

fn lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

fn case(seed: u64, m: usize, n: usize, np: usize) -> (Matrix, Matrix) {
    let mut g = Gen { rng: SplitMix64::new(seed) };
    let x = Matrix::from_vec(m, n, g.vec_normal(m * n, 1.0));
    let w = Matrix::from_vec(n, np, g.vec_normal(n * np, 0.3));
    (x, w)
}

/// Quantize synthetic layers through the engine scheduler, exactly as
/// the pipeline fans them.
fn run_engine(layers: &[(Matrix, Matrix)], threads: usize) -> Vec<LayerQuant> {
    let c = QuantConfig { bits: 2.0, loops: 2, ..QuantConfig::default() };
    let q = c.method.quantizer(c.bit_width().unwrap(), &c);
    let sched = engine::plan(threads, layers.len(), q.parallel_safe());
    engine::run_layers(sched, layers.len(), |li| {
        let (x, w) = &layers[li];
        q.quantize_layer(&LayerCtx::plain(x, w, sched.channel_threads))
    })
    .unwrap()
}

#[test]
fn spans_nest_across_scheduler_threads() {
    let _g = lock();
    obs::enable();
    obs::reset();
    let layers: Vec<_> = (0..6).map(|i| case(20 + i, 48, 8, 6)).collect();
    let out = run_engine(&layers, 4);
    let snap = obs::snapshot();
    obs::disable();
    assert_eq!(out.len(), layers.len());

    // the fan span sits on the calling thread
    assert!(snap.events.iter().any(|e| e.cat == "pool" && e.name == "engine.layers"));

    // plan(4, 6, true) is a 4×1 split, so ≥ 2 worker threads recorded
    let mut worker_tids: Vec<u64> = snap
        .events
        .iter()
        .filter(|e| e.cat == "pool.worker")
        .map(|e| e.tid)
        .collect();
    worker_tids.sort_unstable();
    worker_tids.dedup();
    assert!(worker_tids.len() >= 2, "want ≥ 2 workers, got {worker_tids:?}");

    // one span per layer, each nested (depth + time) inside the worker
    // span on its own thread
    let layer_spans: Vec<_> = snap.events.iter().filter(|e| e.cat == "engine").collect();
    assert_eq!(layer_spans.len(), layers.len());
    for l in &layer_spans {
        assert!(l.depth >= 1, "{} should nest under its worker", l.name);
        let contained = snap.events.iter().any(|w| {
            w.cat == "pool.worker"
                && w.tid == l.tid
                && w.start_ns <= l.start_ns
                && l.start_ns + l.dur_ns <= w.start_ns + w.dur_ns
        });
        assert!(contained, "{} not inside a worker span", l.name);
    }
}

#[test]
fn disabled_path_records_nothing() {
    let _g = lock();
    obs::disable();
    obs::reset();
    let before = obs::events_recorded();
    let layers: Vec<_> = (0..4).map(|i| case(40 + i, 48, 8, 4)).collect();
    let _ = run_engine(&layers, 4);
    assert_eq!(obs::events_recorded(), before, "disabled run recorded");
    let snap = obs::snapshot();
    assert!(snap.events.is_empty());
    assert!(snap.counters.is_empty());
    assert!(snap.hists.is_empty());
}

#[test]
fn chrome_trace_round_trips_through_util_json() {
    let _g = lock();
    obs::enable();
    obs::reset();
    {
        let _outer = obs::span("phase", "phase.quantize");
        let _inner = obs::span("engine", "layer[0]");
    }
    obs::counter("planner.probes", 3);
    let dir = std::env::temp_dir().join("beacon_ptq_obs_trace_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.json");
    obs::write_chrome_trace(&path).unwrap();
    obs::disable();

    let text = std::fs::read_to_string(&path).unwrap();
    let v = Value::parse(&text).expect("trace must be valid JSON");
    assert_eq!(v.get("displayTimeUnit").and_then(|d| d.as_str()), Some("ms"));
    let events = v.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
    // process_name metadata + the two spans
    assert!(events.len() >= 3, "{} trace events", events.len());
    for name in ["phase.quantize", "layer[0]"] {
        assert!(
            events.iter().any(|e| e.get("name").and_then(|n| n.as_str()) == Some(name)),
            "missing span {name}"
        );
    }
    let counters = v.get("beaconCounters").and_then(|c| c.as_obj()).unwrap();
    assert_eq!(counters.get("planner.probes").and_then(|c| c.as_f64()), Some(3.0));
}

#[test]
fn traced_runs_are_bit_identical_to_untraced() {
    let _g = lock();
    let layers: Vec<_> = (0..5).map(|i| case(30 + i, 48, 8, 5)).collect();
    for threads in [1usize, 4] {
        obs::disable();
        obs::reset();
        let plain = run_engine(&layers, threads);
        obs::enable();
        obs::reset();
        let traced = run_engine(&layers, threads);
        obs::disable();
        assert_eq!(plain.len(), traced.len());
        for (li, (a, b)) in plain.iter().zip(&traced).enumerate() {
            let what = format!("t={threads} layer {li}");
            assert_eq!(a.codes, b.codes, "{what}: codes");
            assert_eq!(a.scales, b.scales, "{what}: scales");
            assert_eq!(a.offsets, b.offsets, "{what}: offsets");
            let pb: Vec<u64> = a.dequant.data.iter().map(|v| v.to_bits()).collect();
            let tb: Vec<u64> = b.dequant.data.iter().map(|v| v.to_bits()).collect();
            assert_eq!(pb, tb, "{what}: dequant bits");
        }
    }
}
