//! Integration tests for the memory-observability layer with the
//! tracking allocator actually installed as the global allocator (the
//! lib unit tests can't do that — `#[global_allocator]` is per binary):
//!
//! 1. allocator counters are monotone and peak ≥ live across worker
//!    threads ∈ {1, 4},
//! 2. packed-vs-f32 footprint tracks the storage-bits ratio at every
//!    supported bit width,
//! 3. quantization outputs stay bit-identical with tracing on vs off
//!    while every allocation routes through `TrackingAlloc`,
//! 4. phase spans capture live-heap deltas, and the resident registry
//!    round-trips through `obs::snapshot()`.
//!
//! Allocator counters and the recorder are process-global, so every
//! test takes `lock()`; with all tests serialized, the main thread is
//! the only allocator when assertions read live/peak.

use std::sync::{Mutex, OnceLock};

use beacon_ptq::config::QuantConfig;
use beacon_ptq::data::rng::SplitMix64;
use beacon_ptq::linalg::Matrix;
use beacon_ptq::obs::{self, memory, TrackingAlloc};
use beacon_ptq::quant::alphabet::{alphabet, BitWidth};
use beacon_ptq::quant::engine::{self, LayerCtx, LayerQuant, Quantizer as _};
use beacon_ptq::quant::packing::layer_packed_bytes;
use beacon_ptq::util::prop::Gen;

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

fn lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

fn case(seed: u64, m: usize, n: usize, np: usize) -> (Matrix, Matrix) {
    let mut g = Gen { rng: SplitMix64::new(seed) };
    let x = Matrix::from_vec(m, n, g.vec_normal(m * n, 1.0));
    let w = Matrix::from_vec(n, np, g.vec_normal(n * np, 0.3));
    (x, w)
}

fn run_engine(layers: &[(Matrix, Matrix)], threads: usize) -> Vec<LayerQuant> {
    let c = QuantConfig { bits: 2.0, loops: 2, ..QuantConfig::default() };
    let q = c.method.quantizer(c.bit_width().unwrap(), &c);
    let sched = engine::plan(threads, layers.len(), q.parallel_safe());
    engine::run_layers(sched, layers.len(), |li| {
        let (x, w) = &layers[li];
        q.quantize_layer(&LayerCtx::plain(x, w, sched.channel_threads))
    })
    .unwrap()
}

#[test]
fn allocator_counters_monotone_across_threads() {
    let _g = lock();
    assert!(memory::tracking(), "global allocator must be TrackingAlloc");
    for threads in [1usize, 4] {
        let s0 = memory::stats();
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                std::thread::spawn(move || {
                    let mut keep: Vec<Vec<u8>> = Vec::new();
                    for i in 0..64 {
                        keep.push(vec![t as u8; 4096 + i]);
                    }
                    keep.iter().map(|v| v.len()).sum::<usize>()
                })
            })
            .collect();
        let mut churned = 0usize;
        for h in handles {
            churned += h.join().unwrap();
        }
        let s1 = memory::stats();
        assert!(churned >= threads * 64 * 4096);
        assert!(s1.allocs > s0.allocs, "t={threads}: allocs must grow");
        assert!(
            s1.alloc_bytes >= s0.alloc_bytes + churned as u64,
            "t={threads}: alloc_bytes {} → {} missed {churned} churned",
            s0.alloc_bytes,
            s1.alloc_bytes
        );
        assert!(s1.deallocs >= s0.deallocs, "t={threads}: deallocs monotone");
        assert!(s1.allocs >= s1.deallocs, "t={threads}: frees ≤ allocs");
        assert!(s1.peak_bytes >= s0.peak_bytes, "t={threads}: peak monotone");
        // workers joined and the lock serializes tests, so this thread
        // is the only allocator: the invariant must hold exactly
        let live = memory::live_bytes();
        let peak = memory::peak_bytes();
        assert!(peak >= live, "t={threads}: peak {peak} < live {live}");
    }
}

#[test]
fn packed_footprint_tracks_bits_ratio_per_width() {
    let _g = lock();
    let n = 4096usize;
    let channels = 4usize;
    for width in BitWidth::ALL {
        let alph = alphabet(width);
        let codes: Vec<Vec<f64>> = (0..channels)
            .map(|c| (0..n).map(|i| alph[(i + c) % alph.len()]).collect())
            .collect();
        let (payload, meta) = layer_packed_bytes(&codes, width).unwrap();
        let fp_bytes = (channels * n * 4) as f64;
        let ratio = payload as f64 / fp_bytes;
        let theoretical = f64::from(width.storage_bits()) / 32.0;
        let err = (ratio / theoretical - 1.0).abs();
        assert!(
            err < 0.10,
            "{width:?}: packed/f32 ratio {ratio:.4} strays {err:.3} from \
             theoretical {theoretical:.4}"
        );
        assert_eq!(meta, channels as u64 * 8, "{width:?}: 8 B metadata/channel");
    }
}

#[test]
fn traced_runs_bit_identical_under_tracking_allocator() {
    let _g = lock();
    let layers: Vec<_> = (0..5).map(|i| case(60 + i, 48, 8, 5)).collect();
    for threads in [1usize, 4] {
        obs::disable();
        obs::reset();
        let plain = run_engine(&layers, threads);
        obs::enable();
        obs::reset();
        let traced = run_engine(&layers, threads);
        obs::disable();
        assert_eq!(plain.len(), traced.len());
        for (li, (a, b)) in plain.iter().zip(&traced).enumerate() {
            let what = format!("t={threads} layer {li}");
            assert_eq!(a.codes, b.codes, "{what}: codes");
            assert_eq!(a.scales, b.scales, "{what}: scales");
            assert_eq!(a.offsets, b.offsets, "{what}: offsets");
            let pb: Vec<u64> = a.dequant.data.iter().map(|v| v.to_bits()).collect();
            let tb: Vec<u64> = b.dequant.data.iter().map(|v| v.to_bits()).collect();
            assert_eq!(pb, tb, "{what}: dequant bits");
        }
    }
}

#[test]
fn phase_spans_capture_live_heap_delta() {
    let _g = lock();
    obs::enable();
    obs::reset();
    let sink: Vec<u8>;
    {
        let _s = obs::span("phase", "phase.memtest");
        sink = vec![7u8; 512 * 1024];
    }
    let snap = obs::snapshot();
    obs::disable();
    assert_eq!(sink.len(), 512 * 1024);
    let ev = snap
        .events
        .iter()
        .find(|e| e.name == "phase.memtest")
        .expect("phase span recorded");
    assert!(
        ev.live_close_bytes >= ev.live_open_bytes + 500_000,
        "span must see the 512 KiB allocated inside it: open {} close {}",
        ev.live_open_bytes,
        ev.live_close_bytes
    );
    assert!(
        ev.peak_close_bytes >= ev.live_close_bytes,
        "peak {} < live {} at span close",
        ev.peak_close_bytes,
        ev.live_close_bytes
    );
}

#[test]
fn resident_registry_roundtrips_through_snapshot() {
    let _g = lock();
    obs::enable();
    obs::reset();
    memory::set_resident("test.block", 12_345);
    memory::set_resident("test.block", 23_456); // last write wins
    memory::set_resident("test.other", 99);
    let snap = obs::snapshot();
    obs::disable();
    assert_eq!(snap.resident.get("test.block"), Some(&23_456));
    assert_eq!(snap.resident.get("test.other"), Some(&99));
    obs::reset();
    let snap2 = obs::snapshot();
    assert!(snap2.resident.is_empty(), "reset clears the registry");
}
