//! Mixed precision + mixed method through the plan API: attention
//! projections (qkv/proj) at 2-bit Beacon, MLP layers (fc1/fc2) at
//! 4-bit COMQ — the configuration LeanQuant/COMQ-style loss-aware
//! assignment would pick when attention tolerates aggressive widths but
//! the MLP does not.
//!
//! Prints the resolved per-layer table, the effective bits/weight, and
//! the plan manifest that reproduces the run from one file.
//!
//! ```bash
//! cargo run --release --example mixed_precision
//! ```

use beacon_ptq::config::{PlanBuilder, QuantConfig};
use beacon_ptq::coordinator::report::plan_table;
use beacon_ptq::coordinator::Pipeline;

fn main() -> anyhow::Result<()> {
    let mut pipe = Pipeline::from_artifacts("artifacts", "tiny-sim")?;

    // Base config: 2-bit Beacon everywhere. Overrides are ordered globs,
    // last match wins — the MLP patterns re-route fc1/fc2 to 4-bit COMQ.
    let base = QuantConfig { bits: 2.0, loops: 4, ..QuantConfig::default() };
    let plan = PlanBuilder::uniform(&base)
        .override_layers("blocks.*.qkv.w", "beacon:2")?
        .override_layers("blocks.*.proj.w", "beacon:2")?
        .override_layers("blocks.*.fc?.w", "comq:4+loops=4")?
        .build(pipe.quantizable())?;

    println!("plan label: {}", plan.label());
    println!(
        "effective bits/weight: {:.3}\n",
        plan.effective_bits(|name| pipe.weights_fp.get(name).numel())
    );

    let report = pipe.quantize(&plan)?;
    println!("{}", plan_table(&report).render());
    println!("FP top-1    : {:.2}%", report.fp_top1 * 100.0);
    println!("mixed top-1 : {:.2}%  (drop {:.2}%)",
        report.top1 * 100.0, report.accuracy_drop());

    // every run reproducible from one file: `beacon quantize --config` or
    // QuantPlan::from_file() rebuilds this exact plan
    let out = "artifacts/plan__tiny-sim_mixed.cfg";
    std::fs::write(out, plan.to_manifest())?;
    println!("\nwrote resolved plan manifest to {out}");
    Ok(())
}
