//! Mixed precision + mixed method + mixed *scenario* through the plan
//! API: attention projections at grouped-asymmetric 3-bit Beacon with an
//! outlier sidecar (`beacon:3+g16+asym+k2`), the proj layers at plain
//! 2-bit Beacon, and the MLP at 4-bit COMQ — the shape of configuration
//! a loss-aware assignment picks when attention carries a few dominant
//! weights but tolerates narrow grids once they are split out.
//!
//! With the AOT bundle present (`make artifacts`) the plan runs through
//! [`Pipeline::quantize`] against real tiny-sim activations. Without it
//! — the CI smoke path — a deterministic synthetic model stands in:
//! every layer is quantized with its assignment's own quantizer, the
//! grouped layer is packed into a BPK2 checkpoint, and the round-trip
//! is checked byte-for-byte.
//!
//! ```bash
//! cargo run --release --example mixed_precision
//! ```

use std::path::Path;

use beacon_ptq::config::{PlanBuilder, QuantConfig, QuantPlan};
use beacon_ptq::coordinator::report::plan_table;
use beacon_ptq::coordinator::Pipeline;
use beacon_ptq::data::rng::SplitMix64;
use beacon_ptq::linalg::Matrix;
use beacon_ptq::model::spec::{quantizable_layers, ViTConfig};
use beacon_ptq::model::{PackedLayer, PackedStore};
use beacon_ptq::quant::engine::LayerCtx;
use beacon_ptq::quant::layer_recon_error;
use beacon_ptq::util::prop::Gen;

/// The mixed plan: overrides are ordered globs, last match wins.
fn build_plan(base: &QuantConfig, layers: &[String]) -> anyhow::Result<QuantPlan> {
    PlanBuilder::uniform(base)
        .override_layers("blocks.*.qkv.w", "beacon:3+g16+asym+k2")?
        .override_layers("blocks.*.proj.w", "beacon:2")?
        .override_layers("blocks.*.fc?.w", "comq:4+loops=4")?
        .build(layers)
}

fn main() -> anyhow::Result<()> {
    if Path::new("artifacts/manifest__tiny-sim.json").exists() {
        match run_real() {
            Ok(()) => return Ok(()),
            Err(e) => {
                eprintln!("artifact path failed ({e:#}); falling back to synthetic")
            }
        }
    }
    run_synthetic()
}

/// Quantize + evaluate against the real calibration set.
fn run_real() -> anyhow::Result<()> {
    let mut pipe = Pipeline::from_artifacts("artifacts", "tiny-sim")?;
    let base = QuantConfig { bits: 2.0, loops: 4, ..QuantConfig::default() };
    let plan = build_plan(&base, pipe.quantizable())?;

    println!("plan label: {}", plan.label());
    println!(
        "effective bits/weight: {:.3}\n",
        plan.effective_bits(|name| pipe.weights_fp.get(name).numel())
    );

    let report = pipe.quantize(&plan)?;
    println!("{}", plan_table(&report).render());
    println!("FP top-1    : {:.2}%", report.fp_top1 * 100.0);
    println!(
        "mixed top-1 : {:.2}%  (drop {:.2}%)",
        report.top1 * 100.0,
        report.accuracy_drop()
    );

    // every run reproducible from one file: `beacon quantize --config` or
    // QuantPlan::from_file() rebuilds this exact plan
    let out = "artifacts/plan__tiny-sim_mixed.cfg";
    std::fs::write(out, plan.to_manifest())?;
    println!("\nwrote resolved plan manifest to {out}");
    Ok(())
}

/// Artifact-free walk-through on a synthetic 2-block tiny-sim geometry:
/// per-layer quantize with each assignment's quantizer, then pack the
/// grouped qkv layer into a BPK2 checkpoint and verify the round-trip.
fn run_synthetic() -> anyhow::Result<()> {
    println!("no artifacts found — quantizing a synthetic model\n");
    let cfg = ViTConfig { depth: 2, ..ViTConfig::tiny_sim() };
    let names = quantizable_layers(&cfg);
    let d = cfg.d_model;
    let f = cfg.d_mlp();
    let m = 192; // calibration token rows

    let mut g = Gen { rng: SplitMix64::new(0x317ED) };
    let mut xs: Vec<Matrix> = Vec::new();
    let mut ws: Vec<Matrix> = Vec::new();
    for name in &names {
        let (n, np) = if name.contains("qkv") {
            (d, 3 * d)
        } else if name.contains("fc1") {
            (d, f)
        } else if name.contains("fc2") {
            (f, d)
        } else {
            (d, d)
        };
        xs.push(Matrix::from_vec(m, n, g.vec_normal(m * n, 1.0)));
        let mut w = Matrix::from_vec(n, np, g.vec_normal(n * np, 0.3));
        if name.contains("qkv") {
            // a few dominant weights per layer — the outlier sidecar's
            // reason to exist on the attention recipe
            for (i, v) in w.data.iter_mut().enumerate() {
                if i % 131 == 0 {
                    *v *= 8.0;
                }
            }
        }
        ws.push(w);
    }

    let base = QuantConfig { bits: 2.0, loops: 2, ..QuantConfig::default() };
    let plan = build_plan(&base, &names)?;
    println!("plan label: {}", plan.label());
    let numel = |name: &str| {
        let i = names.iter().position(|n| n == name).unwrap();
        ws[i].rows * ws[i].cols
    };
    println!("effective bits/weight: {:.3}\n", plan.effective_bits(numel));

    let mut packed: Option<PackedLayer> = None;
    for (i, a) in plan.assignments.iter().enumerate() {
        let lq = a
            .quantizer(&plan.base)
            .quantize_layer(&LayerCtx::plain(&xs[i], &ws[i], 0))?;
        let err = layer_recon_error(&xs[i], &ws[i], &lq.dequant);
        println!("  {:<18} {:<22} recon err {err:.4}", a.layer, a.tag());
        if packed.is_none() && a.group_size > 0 {
            let bits = a.to_config(&plan.base).bit_width().unwrap();
            packed = PackedLayer::pack_quant(&a.layer, &lq, bits);
        }
    }

    // the grouped layer rides the BPK2 container; prove the round-trip
    let layer = packed.expect("plan has a grouped layer with on-grid codes");
    let store = PackedStore { layers: vec![layer] };
    let out = std::env::temp_dir().join("mixed_precision_scenario.bpk");
    store.save(&out)?;
    let bytes = std::fs::read(&out)?;
    anyhow::ensure!(&bytes[..4] == b"BPK2", "grouped layer must write BPK2");
    let back = PackedStore::load(&out)?;
    let out2 = std::env::temp_dir().join("mixed_precision_scenario_resave.bpk");
    back.save(&out2)?;
    anyhow::ensure!(bytes == std::fs::read(&out2)?, "BPK2 resave diverged");
    println!(
        "\npacked grouped layer '{}' → {} ({} bytes, BPK2, round-trip verified)",
        back.layers[0].name,
        out.display(),
        bytes.len()
    );

    // every run reproducible from one file
    let manifest = plan.to_manifest();
    let rebuilt = QuantPlan::from_manifest(&manifest, &names)?;
    anyhow::ensure!(rebuilt == plan, "manifest round-trip diverged");
    println!("plan manifest round-trip verified ({} layers)", names.len());
    Ok(())
}
