//! Quickstart: quantize the bundled model to 2 bits with Beacon and
//! evaluate — the happy path of the plan API.
//!
//! ```bash
//! make artifacts                      # once: build AOT bundle + weights
//! cargo run --release --example quickstart
//! ```

use beacon_ptq::config::{PlanBuilder, QuantConfig};
use beacon_ptq::coordinator::Pipeline;

fn main() -> anyhow::Result<()> {
    // Load the AOT bundle: trained FP weights, calibration + eval splits,
    // and the compiled-once HLO graphs (model fwd + the Pallas kernel).
    let mut pipe = Pipeline::from_artifacts("artifacts", "tiny-sim")?;

    // Beacon with integrated grid selection: no scale search, no alpha/beta
    // tuning — just the bit width and the sweep count K. `threads: 0` lets
    // the layer/channel scheduler size itself (BEACON_THREADS env var or
    // the core count); any thread count gives bit-identical results.
    let cfg = QuantConfig { bits: 2.0, loops: 4, threads: 0, ..QuantConfig::default() };

    // Compile the config into a per-layer plan. A uniform build is the
    // flat-config path; chain `.override_layers(pattern, spec)?` here to
    // mix methods/bit widths per layer (see examples/mixed_precision.rs).
    let plan = PlanBuilder::uniform(&cfg).build(pipe.quantizable())?;

    let report = pipe.quantize(&plan)?;
    println!("FP top-1        : {:.2}%", report.fp_top1 * 100.0);
    println!("2-bit top-1     : {:.2}%", report.top1 * 100.0);
    println!("accuracy drop   : {:.2}%", report.accuracy_drop());
    println!("effective bits  : {:.2} / weight", report.effective_bits);
    println!("quantize wall   : {:.2}s", report.quantize_secs);
    Ok(())
}
