//! Bit-width sweep: Beacon (full variant) vs every baseline across the
//! paper's five bit widths — the data behind Tables 1+2 in one run,
//! printed as a plot-ready CSV block and a markdown table.
//!
//! ```bash
//! cargo run --release --example bitwidth_sweep
//! ```

use beacon_ptq::config::{Method, QuantConfig};
use beacon_ptq::coordinator::report::{pct, Table};
use beacon_ptq::coordinator::Pipeline;
use beacon_ptq::quant::alphabet::BitWidth;

fn main() -> anyhow::Result<()> {
    let mut pipe = Pipeline::from_artifacts("artifacts", "tiny-sim")?;
    let fp = pipe.fp_top1()?;
    println!("FP top-1: {:.2}%\n", fp * 100.0);

    let grid = [
        (BitWidth::B158, 6usize),
        (BitWidth::B2, 4),
        (BitWidth::B258, 4),
        (BitWidth::B3, 6),
        (BitWidth::B4, 4),
    ];

    let mut table = Table::new(
        "bit-width sweep — top-1 (%)",
        &["bits", "rtn", "gptq", "comq", "beacon", "beacon-full"],
    );
    println!("csv: bits,rtn,gptq,comq,beacon,beacon_full");
    for (bits, loops) in grid {
        // each sweep point is a uniform QuantPlan compiled from the flat
        // config — the same compilation the quantize_cfg shim performs
        let run = |pipe: &mut Pipeline, qc: QuantConfig| -> anyhow::Result<f64> {
            let plan = pipe.uniform_plan(&qc)?;
            Ok(pipe.quantize(&plan)?.top1)
        };
        let rtn = run(&mut pipe, QuantConfig {
            method: Method::Rtn, bits: bits.0, ..QuantConfig::default()
        })?;
        let gptq = run(&mut pipe, QuantConfig {
            method: Method::Gptq, bits: bits.0, ..QuantConfig::default()
        })?;
        let comq = run(&mut pipe, QuantConfig {
            method: Method::Comq, bits: bits.0, loops, ..QuantConfig::default()
        })?;
        let beacon = run(&mut pipe, QuantConfig {
            method: Method::Beacon, bits: bits.0, loops, ..QuantConfig::default()
        })?;
        let full = run(&mut pipe, QuantConfig {
            method: Method::Beacon,
            bits: bits.0,
            loops,
            error_correction: true,
            centering: true,
            ln_tune: true,
            ..QuantConfig::default()
        })?;
        println!(
            "csv: {},{:.4},{:.4},{:.4},{:.4},{:.4}",
            bits.label(), rtn, gptq, comq, beacon, full
        );
        table.row(vec![
            format!("{}(K={loops})", bits.label()),
            pct(rtn),
            pct(gptq),
            pct(comq),
            pct(beacon),
            pct(full),
        ]);
    }
    println!("\n{}", table.render());
    Ok(())
}
