//! End-to-end driver (DESIGN.md "End-to-end validation"): exercises every
//! layer of the stack on the real workload —
//!
//!   1. load the build-time-trained ViT + calibration/eval splits,
//!   2. evaluate FP top-1 through the PJRT `vit_logits` artifact,
//!   3. quantize all 16 linear layers with the full Beacon pipeline
//!      (error correction → centering → LayerNorm tuning), the Pallas
//!      kernel doing the per-channel sweeps,
//!   4. re-evaluate, print the per-layer reconstruction errors and the
//!      LN-tune loss curve, save the quantized checkpoint, and report the
//!      deployment bit-packing ratio.
//!
//! The output of this run is recorded in EXPERIMENTS.md §E2E.

use beacon_ptq::config::{Method, QuantConfig};
use beacon_ptq::coordinator::Pipeline;
use beacon_ptq::quant::engine::Quantizer as _;
use beacon_ptq::quant::packing::{pack_channel, packed_bytes};

fn main() -> anyhow::Result<()> {
    let mut pipe = Pipeline::from_artifacts("artifacts", "tiny-sim")?;
    let m = pipe.artifacts.manifest.clone();
    println!("== Beacon end-to-end: {} ==", m.cfg.name);
    println!(
        "model: {} params, {} blocks, d_model {}, {} quantizable layers",
        m.cfg.param_count(),
        m.cfg.depth,
        m.cfg.d_model,
        m.quantizable.len()
    );
    println!(
        "calibration: {} images ({} tokens); eval: {} images",
        m.calib_count,
        m.calib_count * m.cfg.tokens(),
        m.eval_count,
    );

    let fp = pipe.fp_top1()?;
    println!("\nFP top-1: {:.2}%", fp * 100.0);

    let qc = QuantConfig {
        method: Method::Beacon,
        bits: 2.0,
        loops: 4,
        error_correction: true,
        centering: true,
        ln_tune: true,
        ..QuantConfig::default()
    };
    println!(
        "\nquantizing with {} (dispatch: dyn Quantizer `{}`) ...",
        qc.label(),
        qc.method.quantizer(qc.bit_width()?, &qc).name()
    );
    let (report, store) = pipe.quantize_cfg_with_weights(&qc)?;

    println!("\nper-layer relative reconstruction error (eq. 1):");
    for row in &report.layers {
        let bar = "#".repeat((row.error * 200.0) as usize);
        println!(
            "  {:<20} {:<14} {:.4} {bar}",
            row.layer,
            format!("{}-{}", row.method.name(), row.bits.label()),
            row.error
        );
    }
    if !report.ln_tune_losses.is_empty() {
        let l = &report.ln_tune_losses;
        println!(
            "\nLN-tune distillation loss: {:.5} -> {:.5} over {} steps",
            l[0],
            l[l.len() - 1],
            l.len()
        );
    }

    println!(
        "\nquantized top-1: {:.2}%  (drop {:.2}%)",
        report.top1 * 100.0,
        report.accuracy_drop()
    );
    println!(
        "quantize {:.2}s, eval {:.2}s",
        report.quantize_secs, report.eval_secs
    );

    // deployment storage: quantize the first layer once more against its
    // true calibration activations and bit-pack the codes
    let (_, acts) = pipe.collect_acts(&pipe.weights_fp.clone())?;
    let lname = &m.quantizable[0];
    let w = pipe.weights_fp.matrix(lname);
    let lq = pipe.beacon_layer(&qc, &acts[0], &acts[0], &w)?;
    let width = qc.bit_width()?;
    let mut packed = 0usize;
    for (j, codes) in lq.codes.iter().enumerate() {
        packed += packed_bytes(&pack_channel(codes, lq.scales[j], lq.offsets[j], width));
    }
    let fp_bytes = w.rows * w.cols * 4;
    println!(
        "\npacked '{lname}': {packed} B vs {fp_bytes} B fp32 ({:.1}x compression)",
        fp_bytes as f64 / packed as f64
    );

    let out = std::path::Path::new("artifacts/quantized__tiny-sim_2bit.bin");
    store.save(out)?;
    println!("saved quantized checkpoint to {out:?}");
    let stats = pipe.runtime.stats();
    println!(
        "\nruntime: {} artifact compilations ({:.0} ms), {} executions ({:.0} ms)",
        stats.compilations, stats.compile_ms, stats.executions, stats.exec_ms
    );
    Ok(())
}
