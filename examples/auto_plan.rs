//! Loss-aware automatic plan search (`--auto-plan` as a library call):
//! probe every candidate `(method, bits)` per layer, greedily allocate
//! widths under an effective-bits budget, and emit the searched plan as
//! a reproducible manifest (`auto_plan_manifest.cfg`).
//!
//! With the AOT bundle present (`make artifacts`) the search runs
//! against the real tiny-sim calibration activations through
//! [`Pipeline::auto_plan`]. Without it — the CI smoke path — a
//! deterministic synthetic model stands in: attention layers draw
//! well-behaved weights while the MLP layers carry heavy outliers, so
//! the planner has a real decision to make (the MLP should win the
//! wider widths).
//!
//! ```bash
//! cargo run --release --example auto_plan
//! # with a Chrome/Perfetto trace of the probe sweep:
//! BEACON_TRACE=auto_plan_trace.json cargo run --release --example auto_plan
//! ```

use std::path::Path;

use beacon_ptq::config::{QuantConfig, QuantPlan, SearchSpace};
use beacon_ptq::coordinator::planner::{search_plan, LayerProbe};
use beacon_ptq::coordinator::report::planner_table;
use beacon_ptq::coordinator::Pipeline;
use beacon_ptq::data::rng::SplitMix64;
use beacon_ptq::linalg::Matrix;
use beacon_ptq::model::spec::{quantizable_layers, ViTConfig};
use beacon_ptq::util::prop::Gen;

const MANIFEST_OUT: &str = "auto_plan_manifest.cfg";
const BUDGET_BITS: f64 = 2.58;

fn main() -> anyhow::Result<()> {
    let trace = beacon_ptq::obs::trace_env();
    if trace.is_some() {
        beacon_ptq::obs::enable();
    }
    run()?;
    if let Some(path) = trace {
        beacon_ptq::obs::write_chrome_trace(Path::new(&path))?;
        println!("trace written to {path} (open in ui.perfetto.dev)");
    }
    Ok(())
}

fn run() -> anyhow::Result<()> {
    if Path::new("artifacts/manifest__tiny-sim.json").exists() {
        match run_real() {
            Ok(()) => return Ok(()),
            Err(e) => {
                eprintln!("artifact path failed ({e:#}); falling back to synthetic")
            }
        }
    }
    run_synthetic()
}

/// Search + run against the real calibration set.
fn run_real() -> anyhow::Result<()> {
    let mut pipe = Pipeline::from_artifacts("artifacts", "tiny-sim")?;
    let base = QuantConfig { bits: 2.0, loops: 4, ..QuantConfig::default() };
    let space = SearchSpace::parse(BUDGET_BITS, Some("beacon,comq"), None)?;
    let (plan, preport) = pipe.auto_plan(&base, &space)?;
    println!("{}", planner_table(&preport).render());
    let report = pipe.quantize(&plan)?;
    println!(
        "searched top-1: {:.2}% at {:.3} effective bits (budget {BUDGET_BITS})",
        100.0 * report.top1,
        report.effective_bits
    );
    emit(&plan)
}

/// Artifact-free search over a synthetic 2-block tiny-sim geometry.
fn run_synthetic() -> anyhow::Result<()> {
    println!("no artifacts found — searching over a synthetic model\n");
    let cfg = ViTConfig { depth: 2, ..ViTConfig::tiny_sim() };
    let names = quantizable_layers(&cfg);
    let d = cfg.d_model;
    let f = cfg.d_mlp();
    let m = 192; // calibration token rows

    let mut g = Gen { rng: SplitMix64::new(0xA070) };
    let mut xs: Vec<Matrix> = Vec::new();
    let mut ws: Vec<Matrix> = Vec::new();
    for name in &names {
        let (n, np) = if name.contains("qkv") {
            (d, 3 * d)
        } else if name.contains("fc1") {
            (d, f)
        } else if name.contains("fc2") {
            (f, d)
        } else {
            (d, d)
        };
        xs.push(Matrix::from_vec(m, n, g.vec_normal(m * n, 1.0)));
        let mut w = Matrix::from_vec(n, np, g.vec_normal(n * np, 0.3));
        if name.contains(".fc") {
            // heavy outliers: every 97th weight blown up 6x — these
            // layers quantize poorly at 2 bits and should win width
            for (i, v) in w.data.iter_mut().enumerate() {
                if i % 97 == 0 {
                    *v *= 6.0;
                }
            }
        }
        ws.push(w);
    }
    let grams: Vec<Matrix> = xs.iter().map(|x| x.gram()).collect();
    let probes: Vec<LayerProbe> = names
        .iter()
        .enumerate()
        .map(|(i, name)| LayerProbe {
            name: name.as_str(),
            x: &xs[i],
            gram: &grams[i],
            w: &ws[i],
            numel: ws[i].rows * ws[i].cols,
        })
        .collect();

    let base = QuantConfig { bits: 2.0, loops: 2, ..QuantConfig::default() };
    let space = SearchSpace::parse(BUDGET_BITS, Some("beacon,comq"), None)?;
    let (plan, preport) = search_plan(&base, &probes, &space)?;

    println!("{}", planner_table(&preport).render());
    println!(
        "searched plan: {}\neffective bits: {:.3} / budget {:.2} ({:.0}% used), {} probes",
        plan.label(),
        preport.effective_bits,
        preport.budget_bits,
        100.0 * preport.budget_utilization(),
        preport.probe_count
    );
    emit(&plan)
}

/// Write the manifest and prove it reproduces the exact plan.
fn emit(plan: &QuantPlan) -> anyhow::Result<()> {
    let text = plan.to_manifest();
    std::fs::write(MANIFEST_OUT, &text)?;
    let layers: Vec<String> =
        plan.assignments.iter().map(|a| a.layer.clone()).collect();
    let back = QuantPlan::from_manifest(&text, &layers)?;
    anyhow::ensure!(back == *plan, "manifest round-trip diverged");
    println!("\nwrote searched plan manifest to {MANIFEST_OUT} (round-trip verified)");
    Ok(())
}
