//! Serve a quantized checkpoint: load the 2-bit weights produced by
//! `quantize_vit` (quantizing on the fly if missing), then answer batched
//! classification requests through the PJRT executable, reporting
//! latency/throughput — the deployment half of the story.
//!
//! The server runs with the tracking allocator installed and the obs
//! recorder on when `BEACON_TRACE=FILE` is set: each request is a
//! `serve.request` span (so the trace shows the request stream next to
//! the heap counter track), request latencies merge into a
//! `serve.request_ns` histogram, and the run ends with a heap
//! scoreboard.
//!
//! ```bash
//! cargo run --release --example serve_quantized [-- <num_requests>]
//! BEACON_TRACE=serve_trace.json cargo run --release --example serve_quantized
//! ```

use std::path::Path;
use std::time::Instant;

use beacon_ptq::config::QuantConfig;
use beacon_ptq::coordinator::Pipeline;
use beacon_ptq::model::WeightStore;
use beacon_ptq::obs::{self, Hist, TrackingAlloc};
use beacon_ptq::runtime::client::{literal_f32, literal_to_f32};

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

fn main() -> anyhow::Result<()> {
    let requests: usize = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(32);
    let trace = obs::trace_env();
    if trace.is_some() {
        obs::enable();
    }

    let mut pipe = Pipeline::from_artifacts("artifacts", "tiny-sim")?;
    let m = pipe.artifacts.manifest.clone();
    let ckpt = Path::new("artifacts/quantized__tiny-sim_2bit.bin");

    let store = if ckpt.exists() {
        println!("loading quantized checkpoint {ckpt:?}");
        WeightStore::load(ckpt, &m.cfg)?
    } else {
        println!("no checkpoint found — quantizing now (2-bit beacon)...");
        let qc = QuantConfig { bits: 2.0, loops: 4, ..QuantConfig::default() };
        let (_, store) = pipe.quantize_cfg_with_weights(&qc)?;
        store.save(ckpt)?;
        store
    };
    obs::memory::set_resident("serve.weight_store", store.resident_bytes());

    // weight literals stay resident; each request only uploads images
    let mut weight_inputs = Vec::new();
    for t in store.ordered() {
        let dims: Vec<i64> = t.shape.iter().map(|d| *d as i64).collect();
        weight_inputs.push(literal_f32(&t.data, &dims)?);
    }

    let b = m.eval_batch;
    let k = m.cfg.num_classes;
    println!(
        "serving {requests} requests of batch {b} ({} images total)\n",
        requests * b
    );

    let mut latencies = Vec::with_capacity(requests);
    let mut request_ns = Hist::default();
    let mut correct = 0usize;
    let mut total = 0usize;
    let t_all = Instant::now();
    for r in 0..requests {
        let span = obs::span_args("serve", || {
            (format!("serve.request[{r}]"), vec![("batch", b.to_string())])
        });
        // rotate through the eval split as the request stream
        let lo = (r * b) % (pipe.eval.count - b + 1);
        let hi = lo + b;
        let mut inputs = weight_inputs.clone();
        inputs.push(literal_f32(
            pipe.eval.batch(lo, hi),
            &[b as i64, m.cfg.image as i64, m.cfg.image as i64, m.cfg.channels as i64],
        )?);
        let t = Instant::now();
        let out = pipe.runtime.exec(&m.vit_logits, &inputs)?;
        let logits = literal_to_f32(&out[0])?;
        let secs = span.finish();
        request_ns.record((secs * 1e9) as u64);
        latencies.push(t.elapsed().as_secs_f64() * 1e3);
        for (bi, item) in (lo..hi).enumerate() {
            let row = &logits[bi * k..(bi + 1) * k];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, c| a.1.partial_cmp(c.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            if pred as i32 == pipe.eval.labels[item] {
                correct += 1;
            }
            total += 1;
        }
    }
    let wall = t_all.elapsed().as_secs_f64();
    obs::merge_hist("serve.request_ns", request_ns);
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = latencies[latencies.len() / 2];
    let p95 = latencies[(latencies.len() * 95 / 100).min(latencies.len() - 1)];
    println!("online accuracy : {:.2}%", 100.0 * correct as f64 / total as f64);
    println!("batch latency   : p50 {p50:.2} ms, p95 {p95:.2} ms");
    println!(
        "throughput      : {:.0} images/s ({} images in {:.2}s)",
        (total as f64) / wall,
        total,
        wall
    );
    if obs::memory::tracking() {
        let s = obs::memory::stats();
        println!(
            "heap            : peak {:.1} MiB, live {:.1} MiB \
             ({} allocs / {} frees)",
            s.peak_bytes as f64 / (1 << 20) as f64,
            s.live_bytes as f64 / (1 << 20) as f64,
            s.allocs,
            s.deallocs
        );
    }
    if let Some(path) = trace {
        obs::write_chrome_trace(Path::new(&path))?;
        println!("trace written to {path} (open in ui.perfetto.dev)");
    }
    Ok(())
}
