//! Packed-weight serving: quantize a small model, ship it as a BPK1
//! [`PackedStore`], and serve batched requests straight off the packed
//! bit streams through the fused unpack-dequant-GEMM kernel — the
//! deployment half of the paper's memory claim, measured rather than
//! asserted.
//!
//! For each bit width (4-bit, then 2-bit) the run:
//!
//! 1. quantizes a deterministic synthetic model with native Beacon and
//!    writes the packed checkpoint to disk (sources are dropped);
//! 2. serves the request stream twice from that same file — once as a
//!    dense f32 deployment (channels unpacked to f32 at load), once
//!    fully packed (fused kernel, no weight matrix ever materialized) —
//!    measuring weight resident bytes and the phase's peak-heap delta
//!    with the tracking allocator;
//! 3. asserts the packed path stays under the storage-ratio cap
//!    (≤ 0.5× f32 at 4-bit, ≤ 0.3× at 2-bit) on both measures, and that
//!    the fused `packed_matvec` is bit-identical to unpack-then-matvec
//!    at 1 and 4 threads.
//!
//! ```bash
//! cargo run --release --example serve_quantized [-- <num_requests>]
//! BEACON_TRACE=serve_trace.json cargo run --release --example serve_quantized
//! ```

use std::path::{Path, PathBuf};
use std::time::Instant;

use beacon_ptq::config::{Method, QuantConfig};
use beacon_ptq::coordinator::report::Table;
use beacon_ptq::data::rng::SplitMix64;
use beacon_ptq::linalg::{
    packed_gemm, packed_matvec, packed_matvec_threads, Matrix,
};
use beacon_ptq::model::{PackedLayer, PackedStore};
use beacon_ptq::obs::{self, Hist, TrackingAlloc};
use beacon_ptq::quant::alphabet::BitWidth;
use beacon_ptq::quant::engine::{LayerCtx, Quantizer as _};
use beacon_ptq::quant::packing::unpack_channel;
use beacon_ptq::util::prop::Gen;

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

/// Synthetic model geometry: weight-dominant layers so the weight store
/// (not activations) decides both paths' footprints.
const LAYERS: usize = 6;
const N: usize = 256; // channel length (weight rows)
const NP: usize = 256; // channels per layer (weight cols)
const CALIB_ROWS: usize = 320; // ≥ N so the QR prefactor is well-posed
const BATCH: usize = 8;

struct WidthResult {
    label: String,
    f32_resident: u64,
    f32_peak: u64,
    packed_resident: u64,
    packed_peak: u64,
    cap: f64,
    p50_ms: f64,
    p95_ms: f64,
}

fn main() -> anyhow::Result<()> {
    let requests: usize = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(32);
    let trace = obs::trace_env();
    if trace.is_some() {
        obs::enable();
    }

    let mut rows = Vec::new();
    for (width, cap) in [(BitWidth::B4, 0.5), (BitWidth::B2, 0.3)] {
        rows.push(run_width(width, cap, requests)?);
    }

    let mut t = Table::new(
        "packed vs f32 serving footprint",
        &[
            "width", "f32 resident", "packed resident", "ratio",
            "f32 peak", "packed peak", "ratio", "cap", "p50/p95 ms",
        ],
    );
    for r in &rows {
        t.row(vec![
            r.label.clone(),
            mib(r.f32_resident),
            mib(r.packed_resident),
            format!("{:.2}", r.packed_resident as f64 / r.f32_resident as f64),
            mib(r.f32_peak),
            mib(r.packed_peak),
            format!("{:.2}", r.packed_peak as f64 / r.f32_peak as f64),
            format!("{:.2}", r.cap),
            format!("{:.2}/{:.2}", r.p50_ms, r.p95_ms),
        ]);
    }
    println!("\n{}", t.render());

    if obs::memory::tracking() {
        let s = obs::memory::stats();
        println!(
            "heap: peak {} live {} ({} allocs / {} frees)",
            mib(s.peak_bytes),
            mib(s.live_bytes),
            s.allocs,
            s.deallocs
        );
    }
    if let Some(path) = trace {
        obs::write_chrome_trace(Path::new(&path))?;
        println!("trace written to {path} (open in ui.perfetto.dev)");
    }
    Ok(())
}

fn mib(b: u64) -> String {
    format!("{:.2} MiB", b as f64 / (1 << 20) as f64)
}

fn ckpt_path(width: BitWidth) -> PathBuf {
    let dir = std::env::temp_dir().join("beacon_ptq_serve");
    std::fs::create_dir_all(&dir).expect("create checkpoint dir");
    dir.join(format!("serve_{}bit.bpk", width.storage_bits()))
}

/// Quantize the synthetic model with native Beacon and write the packed
/// checkpoint. Everything built here (weights, activations, codes) goes
/// out of scope on return — serving sees only the file.
fn build_checkpoint(width: BitWidth, path: &Path) -> anyhow::Result<()> {
    let span = obs::span_args("serve", || {
        (format!("serve.quantize[{}]", width.label()), Vec::new())
    });
    let qc = QuantConfig { bits: width.0, loops: 2, ..QuantConfig::default() };
    let quantizer = Method::Beacon.quantizer(width, &qc);
    let mut g = Gen { rng: SplitMix64::new(0x5E12F + width.storage_bits() as u64) };
    let mut layers = Vec::with_capacity(LAYERS);
    for li in 0..LAYERS {
        let x = Matrix::from_vec(
            CALIB_ROWS,
            N,
            g.vec_normal(CALIB_ROWS * N, 1.0),
        );
        let w = Matrix::from_vec(N, NP, g.vec_normal(N * NP, 0.3));
        let lq = quantizer.quantize_layer(&LayerCtx::plain(&x, &w, 1))?;
        let name = format!("layer.{li}.w");
        let packed =
            PackedLayer::pack(&name, &lq.codes, &lq.scales, &lq.offsets, width)
                .ok_or_else(|| {
                    anyhow::anyhow!("{name}: beacon codes fell off the grid")
                })?;
        layers.push(packed);
    }
    let store = PackedStore { layers };
    store.save(path)?;
    span.finish();
    println!(
        "{}: packed checkpoint written to {path:?} ({})",
        width.label(),
        mib(store.resident_bytes())
    );
    Ok(())
}

/// `dot` with an f32 weight vector — the dense-deployment twin of the
/// fused kernel's LUT expansion (same 4-lane accumulation order, so both
/// serving paths produce bit-identical outputs).
fn dot_wf32(w: &[f32], x: &[f64]) -> f64 {
    debug_assert_eq!(w.len(), x.len());
    let n = w.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += f64::from(w[i]) * x[i];
        s1 += f64::from(w[i + 1]) * x[i + 1];
        s2 += f64::from(w[i + 2]) * x[i + 2];
        s3 += f64::from(w[i + 3]) * x[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += f64::from(w[i]) * x[i];
    }
    s
}

/// Deterministic request stream: `requests` batches of `BATCH`×`N`.
fn request_batch(r: usize) -> Matrix {
    let mut g = Gen { rng: SplitMix64::new(0x5EED_0000 ^ r as u64) };
    Matrix::from_vec(BATCH, N, g.vec_normal(BATCH * N, 1.0))
}

fn run_width(
    width: BitWidth,
    cap: f64,
    requests: usize,
) -> anyhow::Result<WidthResult> {
    println!("=== {} packed serving ===", width.label());
    let path = ckpt_path(width);
    build_checkpoint(width, &path)?;

    // ---- dense f32 deployment: unpack every channel to f32 at load ----
    let live0 = obs::memory::reset_peak();
    let f32_layers: Vec<Vec<Vec<f32>>> = {
        let store = PackedStore::load(&path)?;
        store
            .layers
            .iter()
            .map(|l| {
                l.channels
                    .iter()
                    .map(|c| unpack_channel(c, l.width))
                    .collect()
            })
            .collect()
        // `store` (the packed form) drops here: the dense deployment
        // keeps only f32 weights resident
    };
    let f32_resident: u64 = f32_layers
        .iter()
        .flatten()
        .map(|c| (c.len() * 4 + std::mem::size_of::<Vec<f32>>()) as u64)
        .sum();
    obs::memory::set_resident("serve.f32_store", f32_resident);

    let mut f32_out_probe = Vec::new();
    for r in 0..requests {
        let x = request_batch(r);
        let mut out = Matrix::zeros(BATCH, NP);
        for layer in &f32_layers {
            for b in 0..BATCH {
                for (j, ch) in layer.iter().enumerate() {
                    out[(b, j)] += dot_wf32(ch, x.row(b));
                }
            }
        }
        if r == 0 {
            f32_out_probe = out.data.clone();
        }
    }
    let f32_peak = obs::memory::peak_bytes().saturating_sub(live0);
    drop(f32_layers);

    // ---- packed deployment: fused kernel off the bit streams ----
    let live0 = obs::memory::reset_peak();
    let store = PackedStore::load(&path)?;
    let luts: Vec<Vec<Vec<f32>>> =
        store.layers.iter().map(PackedLayer::luts).collect();
    let lut_bytes: u64 = luts
        .iter()
        .flatten()
        .map(|l| (l.len() * 4 + std::mem::size_of::<Vec<f32>>()) as u64)
        .sum();
    let packed_resident = store.resident_bytes() + lut_bytes;
    obs::memory::set_resident("serve.packed_store", packed_resident);

    let threads = beacon_ptq::util::pool::resolve_threads(0);
    let mut latencies = Vec::with_capacity(requests);
    let mut request_ns = Hist::default();
    let mut packed_out_probe = Vec::new();
    let t_all = Instant::now();
    for r in 0..requests {
        let x = request_batch(r);
        let span = obs::span_args("serve", || {
            (
                format!("serve.request[{r}]"),
                vec![("batch", BATCH.to_string())],
            )
        });
        let t = Instant::now();
        let mut out = Matrix::zeros(BATCH, NP);
        for (l, layer) in store.layers.iter().enumerate() {
            let cols = layer.kernel_cols(&luts[l]);
            let y = packed_gemm(&cols, &x, threads);
            for (o, v) in out.data.iter_mut().zip(&y.data) {
                *o += v;
            }
        }
        let secs = span.finish();
        request_ns.record((secs * 1e9) as u64);
        latencies.push(t.elapsed().as_secs_f64() * 1e3);
        if r == 0 {
            packed_out_probe = out.data.clone();
        }
    }
    let wall = t_all.elapsed().as_secs_f64();
    let packed_peak = obs::memory::peak_bytes().saturating_sub(live0);
    obs::merge_hist("serve.request_ns", request_ns);

    // both serving paths share the 4-lane dot order: bit-identical
    assert_eq!(f32_out_probe.len(), packed_out_probe.len());
    for (a, b) in f32_out_probe.iter().zip(&packed_out_probe) {
        assert_eq!(a.to_bits(), b.to_bits(), "f32 vs fused serving diverged");
    }

    // fused packed_matvec ≡ unpack-then-matvec, bit for bit, at 1 and 4
    // threads (the ISSUE's kernel-correctness contract)
    let mut g = Gen { rng: SplitMix64::new(0xB17) };
    let xv = g.vec_normal(N, 1.0);
    for layer in &store.layers {
        let luts = layer.luts();
        let cols = layer.kernel_cols(&luts);
        // reference: unpacked channels as matrix rows → matvec
        let rows: Vec<Vec<f64>> = layer
            .channels
            .iter()
            .map(|c| {
                unpack_channel(c, layer.width)
                    .into_iter()
                    .map(f64::from)
                    .collect()
            })
            .collect();
        let row_refs: Vec<&[f64]> = rows.iter().map(Vec::as_slice).collect();
        let wt = Matrix::from_rows(&row_refs);
        let want = wt.matvec(&xv);
        let fused1 = packed_matvec(&cols, &xv);
        let fused4 = packed_matvec_threads(&cols, &xv, 4);
        for j in 0..NP {
            assert_eq!(
                want[j].to_bits(),
                fused1[j].to_bits(),
                "{}: fused t=1 diverged at channel {j}",
                layer.name
            );
            assert_eq!(
                want[j].to_bits(),
                fused4[j].to_bits(),
                "{}: fused t=4 diverged at channel {j}",
                layer.name
            );
        }
    }
    println!("{}: fused ≡ unpack-then-matvec at t=1 and t=4", width.label());

    // the storage-ratio caps the ISSUE acceptance criteria pin
    assert!(
        (packed_resident as f64) <= cap * f32_resident as f64,
        "{}: packed resident {} vs f32 {} exceeds cap {cap}",
        width.label(),
        packed_resident,
        f32_resident
    );
    assert!(
        (packed_peak as f64) <= cap * f32_peak as f64,
        "{}: packed peak {} vs f32 {} exceeds cap {cap}",
        width.label(),
        packed_peak,
        f32_peak
    );

    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = latencies[latencies.len() / 2];
    let p95 = latencies[(latencies.len() * 95 / 100).min(latencies.len() - 1)];
    println!(
        "{}: {} requests ({} rows) in {:.2}s — p50 {:.2} ms, p95 {:.2} ms, \
         packed/f32 resident {:.2}×, peak {:.2}×\n",
        width.label(),
        requests,
        requests * BATCH,
        wall,
        p50,
        p95,
        packed_resident as f64 / f32_resident as f64,
        packed_peak as f64 / f32_peak as f64
    );

    Ok(WidthResult {
        label: width.label(),
        f32_resident,
        f32_peak,
        packed_resident,
        packed_peak,
        cap,
        p50_ms: p50,
        p95_ms: p95,
    })
}
