//! Packed-weight serving through the serve subsystem: quantize a small
//! model, ship it as a BPK1 [`PackedStore`], and serve batched requests
//! straight off the packed bit streams via [`Server`] — the deployment
//! half of the paper's memory claim, measured rather than asserted.
//!
//! For each bit width (4-bit, then 2-bit) the run:
//!
//! 1. quantizes a deterministic synthetic model with native Beacon and
//!    writes the packed checkpoint to disk (sources are dropped);
//! 2. serves the request stream twice from that same file — once as a
//!    dense f32 deployment (channels unpacked to f32 at load, layers
//!    chained with the same 4-lane dot the fused kernel uses), once
//!    through the batching server on a resident [`PackedModel`] (fused
//!    kernel, no weight matrix ever materialized) — measuring weight
//!    resident bytes and each phase's peak-heap delta with the tracking
//!    allocator;
//! 3. asserts every batched response is bit-identical to the dense f32
//!    twin, that the sequential packed path is thread-count invariant,
//!    and that the packed path stays under the storage-ratio cap
//!    (≤ 0.5× f32 at 4-bit, ≤ 0.3× at 2-bit) on resident and peak.
//!
//! ```bash
//! cargo run --release --example serve_quantized [-- <num_requests>]
//! BEACON_TRACE=serve_trace.json cargo run --release --example serve_quantized
//! ```

use std::path::{Path, PathBuf};
use std::sync::Arc;

use beacon_ptq::config::{Method, QuantConfig};
use beacon_ptq::coordinator::report::{serve_table, Table};
use beacon_ptq::data::rng::SplitMix64;
use beacon_ptq::linalg::Matrix;
use beacon_ptq::model::{PackedLayer, PackedStore};
use beacon_ptq::obs::{self, TrackingAlloc};
use beacon_ptq::quant::alphabet::BitWidth;
use beacon_ptq::quant::engine::{LayerCtx, Quantizer as _};
use beacon_ptq::quant::packing::unpack_channel;
use beacon_ptq::serve::{PackedModel, Response, ServeConfig, Server};
use beacon_ptq::util::prop::Gen;

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

/// Synthetic model geometry: square weight-dominant layers (so the chain
/// is well-formed and the weight store, not activations, decides both
/// paths' footprints).
const LAYERS: usize = 6;
const N: usize = 256; // channel length (weight rows)
const NP: usize = 256; // channels per layer (weight cols) — square: chains
const CALIB_ROWS: usize = 320; // ≥ N so the QR prefactor is well-posed
const BATCH: usize = 8;

struct WidthResult {
    label: String,
    f32_resident: u64,
    f32_peak: u64,
    packed_resident: u64,
    packed_peak: u64,
    cap: f64,
    p50_ms: f64,
    p95_ms: f64,
}

fn main() -> anyhow::Result<()> {
    let requests: usize = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(32);
    let trace = obs::trace_env();
    if trace.is_some() {
        obs::enable();
    }

    let mut rows = Vec::new();
    for (width, cap) in [(BitWidth::B4, 0.5), (BitWidth::B2, 0.3)] {
        rows.push(run_width(width, cap, requests)?);
    }

    let mut t = Table::new(
        "packed vs f32 serving footprint",
        &[
            "width", "f32 resident", "packed resident", "ratio",
            "f32 peak", "packed peak", "ratio", "cap", "p50/p95 ms",
        ],
    );
    for r in &rows {
        t.row(vec![
            r.label.clone(),
            mib(r.f32_resident),
            mib(r.packed_resident),
            format!("{:.2}", r.packed_resident as f64 / r.f32_resident as f64),
            mib(r.f32_peak),
            mib(r.packed_peak),
            format!("{:.2}", r.packed_peak as f64 / r.f32_peak as f64),
            format!("{:.2}", r.cap),
            format!("{:.2}/{:.2}", r.p50_ms, r.p95_ms),
        ]);
    }
    println!("\n{}", t.render());

    if obs::memory::tracking() {
        let s = obs::memory::stats();
        println!(
            "heap: peak {} live {} ({} allocs / {} frees)",
            mib(s.peak_bytes),
            mib(s.live_bytes),
            s.allocs,
            s.deallocs
        );
    }
    if let Some(path) = trace {
        obs::write_chrome_trace(Path::new(&path))?;
        println!("trace written to {path} (open in ui.perfetto.dev)");
    }
    Ok(())
}

fn mib(b: u64) -> String {
    format!("{:.2} MiB", b as f64 / (1 << 20) as f64)
}

fn ckpt_path(width: BitWidth) -> PathBuf {
    let dir = std::env::temp_dir().join("beacon_ptq_serve");
    std::fs::create_dir_all(&dir).expect("create checkpoint dir");
    dir.join(format!("serve_{}bit.bpk", width.storage_bits()))
}

/// Quantize the synthetic model with native Beacon and write the packed
/// checkpoint. Everything built here (weights, activations, codes) goes
/// out of scope on return — serving sees only the file.
fn build_checkpoint(width: BitWidth, path: &Path) -> anyhow::Result<()> {
    let span = obs::span_args("serve", || {
        (format!("serve.quantize[{}]", width.label()), Vec::new())
    });
    let qc = QuantConfig { bits: width.0, loops: 2, ..QuantConfig::default() };
    let quantizer = Method::Beacon.quantizer(width, &qc);
    let mut g = Gen { rng: SplitMix64::new(0x5E12F + width.storage_bits() as u64) };
    let mut layers = Vec::with_capacity(LAYERS);
    for li in 0..LAYERS {
        let x = Matrix::from_vec(
            CALIB_ROWS,
            N,
            g.vec_normal(CALIB_ROWS * N, 1.0),
        );
        let w = Matrix::from_vec(N, NP, g.vec_normal(N * NP, 0.3));
        let lq = quantizer.quantize_layer(&LayerCtx::plain(&x, &w, 1))?;
        let name = format!("layer.{li}.w");
        let packed =
            PackedLayer::pack(&name, &lq.codes, &lq.scales, &lq.offsets, width)
                .ok_or_else(|| {
                    anyhow::anyhow!("{name}: beacon codes fell off the grid")
                })?;
        layers.push(packed);
    }
    let store = PackedStore { layers };
    store.save(path)?;
    span.finish();
    println!(
        "{}: packed checkpoint written to {path:?} ({})",
        width.label(),
        mib(store.resident_bytes())
    );
    Ok(())
}

/// `dot` with an f32 weight vector — the dense-deployment twin of the
/// fused kernel's LUT expansion (same 4-lane accumulation order, so both
/// serving paths produce bit-identical outputs).
fn dot_wf32(w: &[f32], x: &[f64]) -> f64 {
    debug_assert_eq!(w.len(), x.len());
    let n = w.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += f64::from(w[i]) * x[i];
        s1 += f64::from(w[i + 1]) * x[i + 1];
        s2 += f64::from(w[i + 2]) * x[i + 2];
        s3 += f64::from(w[i + 3]) * x[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += f64::from(w[i]) * x[i];
    }
    s
}

/// Chain the dense f32 layers over one request — channel by channel with
/// [`dot_wf32`], exactly the lane order `packed_matvec`/`packed_gemm`
/// use, so the result is bit-identical to the served packed path.
fn dense_forward(layers: &[Vec<Vec<f32>>], x: &[f64]) -> Vec<f64> {
    let mut act = x.to_vec();
    for layer in layers {
        act = layer.iter().map(|ch| dot_wf32(ch, &act)).collect();
    }
    act
}

/// Deterministic request stream: one `N`-dim vector per request.
fn request(r: usize) -> Vec<f64> {
    let mut g = Gen { rng: SplitMix64::new(0x5EED_0000 ^ r as u64) };
    g.vec_normal(N, 1.0)
}

fn run_width(
    width: BitWidth,
    cap: f64,
    requests: usize,
) -> anyhow::Result<WidthResult> {
    println!("=== {} packed serving ===", width.label());
    let path = ckpt_path(width);
    build_checkpoint(width, &path)?;
    let xs: Vec<Vec<f64>> = (0..requests).map(request).collect();

    // ---- dense f32 deployment twin: unpack every channel at load ----
    let live0 = obs::memory::reset_peak();
    let f32_layers: Vec<Vec<Vec<f32>>> = {
        let store = PackedStore::load(&path)?;
        store
            .layers
            .iter()
            .map(|l| {
                l.channels
                    .iter()
                    .map(|c| unpack_channel(c, l.width))
                    .collect()
            })
            .collect()
        // `store` (the packed form) drops here: the dense deployment
        // keeps only f32 weights resident
    };
    let f32_resident: u64 = f32_layers
        .iter()
        .flatten()
        .map(|c| (c.len() * 4 + std::mem::size_of::<Vec<f32>>()) as u64)
        .sum();
    obs::memory::set_resident("serve.f32_store", f32_resident);
    let dense_out: Vec<Vec<f64>> =
        xs.iter().map(|x| dense_forward(&f32_layers, x)).collect();
    let f32_peak = obs::memory::peak_bytes().saturating_sub(live0);
    drop(f32_layers);

    // ---- packed deployment: batching server on the resident model ----
    let live0 = obs::memory::reset_peak();
    let model = Arc::new(PackedModel::load(&path)?);
    let packed_resident = model.resident_bytes();
    let (server, client) = Server::start(
        Arc::clone(&model),
        ServeConfig {
            label: format!("packed {}", width.label()),
            max_batch: BATCH,
            ..ServeConfig::default()
        },
    );
    let handles: Vec<_> = xs.iter().map(|x| client.submit(x.clone())).collect();
    drop(client);
    let responses: Vec<Response> =
        handles.into_iter().map(|h| h.wait()).collect();
    let report = server.shutdown();
    let packed_peak = obs::memory::peak_bytes().saturating_sub(live0);
    print!("{}", serve_table(&report).render());

    // every batched response ≡ the dense f32 twin, bit for bit: the
    // fused-vs-dense contract, now checked through the server
    for (r, resp) in responses.iter().enumerate() {
        let want = &dense_out[r];
        assert_eq!(resp.output.len(), want.len());
        for (j, (a, b)) in resp.output.iter().zip(want).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{}: request {r} channel {j}: fused serving diverged \
                 from the dense f32 path",
                width.label()
            );
        }
    }
    println!(
        "{}: {} batched responses bit-identical to the dense f32 twin",
        width.label(),
        responses.len()
    );

    // the sequential packed reference is thread-count invariant
    for x in xs.iter().take(4) {
        let t1 = model.forward_one(x, 1);
        let t4 = model.forward_one(x, 4);
        for (a, b) in t1.iter().zip(&t4) {
            assert_eq!(a.to_bits(), b.to_bits(), "forward_one t=1 vs t=4");
        }
    }
    println!("{}: forward_one invariant at t=1 and t=4", width.label());

    // the storage-ratio caps the ISSUE acceptance criteria pin
    assert!(
        (packed_resident as f64) <= cap * f32_resident as f64,
        "{}: packed resident {} vs f32 {} exceeds cap {cap}",
        width.label(),
        packed_resident,
        f32_resident
    );
    assert!(
        (packed_peak as f64) <= cap * f32_peak as f64,
        "{}: packed peak {} vs f32 {} exceeds cap {cap}",
        width.label(),
        packed_peak,
        f32_peak
    );

    Ok(WidthResult {
        label: width.label(),
        f32_resident,
        f32_peak,
        packed_resident,
        packed_peak,
        cap,
        p50_ms: report.latency_ns.p50 as f64 / 1e6,
        p95_ms: report.latency_ns.p95 as f64 / 1e6,
    })
}
