"""L2 graph tests: parameter contract, forward shapes, activation collection
order, LN-tune step behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data
from compile.common import (CONFIGS, ln_param_names, param_spec,
                            quantizable_layers)
from compile.model import (collect_acts_fn, forward, init_params,
                           ln_tune_step_fn, logits_fn, params_to_dict)

CFG = CONFIGS["tiny-sim"]


@pytest.fixture(scope="module")
def params():
    return [jnp.asarray(p) for p in init_params(CFG, seed=0)]


@pytest.fixture(scope="module")
def images():
    imgs, _ = data.generate(CFG, 2, 4)
    return jnp.asarray(imgs)


class TestParamSpec:
    def test_count(self):
        # 4 stem + 12/block + 4 tail
        assert len(param_spec(CFG)) == 4 + 12 * CFG.depth + 4

    def test_quantizable_subset(self):
        names = {n for n, _ in param_spec(CFG)}
        for q in quantizable_layers(CFG):
            assert q in names

    def test_quantizable_shapes_are_matrices(self):
        spec = dict(param_spec(CFG))
        for q in quantizable_layers(CFG):
            assert len(spec[q]) == 2

    def test_ln_names_subset(self):
        names = {n for n, _ in param_spec(CFG)}
        for n in ln_param_names(CFG):
            assert n in names

    def test_init_deterministic(self):
        a = init_params(CFG, seed=0)
        b = init_params(CFG, seed=0)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)

    def test_init_seed_sensitivity(self):
        a = init_params(CFG, seed=0)
        b = init_params(CFG, seed=1)
        assert any(not np.array_equal(x, y) for x, y in zip(a, b))


class TestForward:
    def test_logits_shape(self, params, images):
        logits = forward(CFG, params, images)
        assert logits.shape == (4, CFG.num_classes)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_acts_order_and_shapes(self, params, images):
        _, acts = forward(CFG, params, images, want_acts=True)
        qnames = quantizable_layers(CFG)
        assert len(acts) == len(qnames)
        spec = dict(param_spec(CFG))
        m = 4 * CFG.tokens
        for name, a in zip(qnames, acts):
            assert a.shape == (m, spec[name][0]), name

    def test_logits_fn_matches_forward(self, params, images):
        (l1,) = logits_fn(CFG)(*params, images)
        l2 = forward(CFG, params, images)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2))

    def test_collect_fn_consistent(self, params, images):
        out = collect_acts_fn(CFG)(*params, images)
        l2 = forward(CFG, params, images)
        np.testing.assert_allclose(np.asarray(out[0]), np.asarray(l2))
        assert len(out) == 1 + len(quantizable_layers(CFG))

    def test_weight_perturbation_changes_logits(self, params, images):
        """Quantizable weights actually participate in the graph."""
        spec = [n for n, _ in param_spec(CFG)]
        idx = spec.index(quantizable_layers(CFG)[0])
        p2 = list(params)
        p2[idx] = p2[idx] + 0.1
        a = forward(CFG, params, images)
        b = forward(CFG, p2, images)
        assert not np.allclose(np.asarray(a), np.asarray(b))


class TestLnTune:
    def test_step_returns_loss_and_ln_params(self, params, images):
        step, ln_idx = ln_tune_step_fn(CFG)
        teacher = forward(CFG, params, images)
        out = step(*params, images, teacher, jnp.float32(0.01))
        assert len(out) == 1 + len(ln_idx)
        assert float(out[0]) >= 0.0

    def test_zero_loss_at_teacher(self, params, images):
        """Student == teacher -> loss 0, gradient step is a no-op."""
        step, ln_idx = ln_tune_step_fn(CFG)
        teacher = forward(CFG, params, images)
        out = step(*params, images, teacher, jnp.float32(0.5))
        assert float(out[0]) < 1e-10
        for j, i in enumerate(ln_idx):
            np.testing.assert_allclose(
                np.asarray(out[1 + j]), np.asarray(params[i]), atol=1e-5
            )

    def test_step_reduces_loss(self, params, images):
        """A few steps on perturbed LN params must reduce the distill loss."""
        step, ln_idx = ln_tune_step_fn(CFG)
        teacher = forward(CFG, params, images)
        perturbed = list(params)
        rng = np.random.default_rng(0)
        for i in ln_idx:
            perturbed[i] = params[i] * (
                1.0 + 0.2 * rng.normal(size=params[i].shape).astype(np.float32)
            )
        losses = []
        cur = perturbed
        for _ in range(15):
            out = step(*cur, images, teacher, jnp.float32(0.5))
            losses.append(float(out[0]))
            cur = list(cur)
            for j, i in enumerate(ln_idx):
                cur[i] = out[1 + j]
        assert losses[-1] < losses[0] * 0.8, losses
        # and it is monotone at this lr on this problem
        assert all(b <= a for a, b in zip(losses, losses[1:])), losses
