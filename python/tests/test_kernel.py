"""Pallas kernel vs the numpy oracle — the CORE L1 correctness signal.

The kernel and oracle share the tie-breaking contract (ascending-alphabet,
strict >, -inf on zero denominators), so on well-conditioned inputs the
outputs match *exactly*; hypothesis sweeps shapes/bit-widths/seeds with an
objective-level tolerance for the rare f32-vs-f64 near-tie flip.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.common import alphabet
from compile.kernels import ref
from compile.kernels.beacon import beacon_layer, beacon_layer_dequant

def make_layer(seed, m, n, np_):
    rng = np.random.default_rng(seed)
    X = (rng.normal(size=(m, n)) @ (np.eye(n) + 0.2 * rng.normal(size=(n, n)))
         ).astype(np.float32)
    W = (rng.normal(size=(n, np_)) * 0.25).astype(np.float32)
    _, R = np.linalg.qr(X)
    return X, R.astype(np.float32), W


class TestBeaconKernelExact:
    @pytest.mark.parametrize("bits", [1.58, 2.0, 3.0])
    @pytest.mark.parametrize("loops", [0, 1, 4])
    def test_matches_ref(self, bits, loops):
        _, R, W = make_layer(0, 64, 16, 6)
        A = alphabet(bits)
        Q, c = beacon_layer(R, R, W, alphabet=tuple(A), loops=loops)
        Q, c = np.asarray(Q), np.asarray(c)
        for j in range(W.shape[1]):
            q_ref, c_ref = ref.beacon_channel(R, R, W[:, j], A, loops)
            np.testing.assert_array_equal(Q[:, j], q_ref)
            np.testing.assert_allclose(c[j], c_ref, rtol=1e-4)

    def test_error_correction_path(self):
        X, _, W = make_layer(1, 64, 12, 4)
        rng = np.random.default_rng(5)
        Xt = X + 0.1 * rng.normal(size=X.shape).astype(np.float32)
        U, R = np.linalg.qr(Xt)
        L = (U.T @ X).astype(np.float32)
        A = alphabet(2.0)
        Q, c = beacon_layer(L, R.astype(np.float32), W, alphabet=tuple(A), loops=3)
        for j in range(W.shape[1]):
            q_ref, c_ref = ref.beacon_channel(L, R, W[:, j], A, 3)
            np.testing.assert_array_equal(np.asarray(Q)[:, j], q_ref)
            np.testing.assert_allclose(np.asarray(c)[j], c_ref, rtol=1e-4)

    def test_alphabet_padding_inert(self):
        _, R, W = make_layer(2, 48, 10, 4)
        A = tuple(alphabet(2.0))
        q1, c1 = beacon_layer(R, R, W, alphabet=A, loops=2)
        q2, c2 = beacon_layer(R, R, W, alphabet=A + (A[-1],) * 4, loops=2)
        np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
        np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))

    def test_dequant_shape_and_grid(self):
        _, R, W = make_layer(3, 48, 8, 5)
        A = alphabet(2.0)
        D = np.asarray(beacon_layer_dequant(R, R, W, alphabet=tuple(A), loops=2))
        assert D.shape == W.shape
        # each column must be a scalar multiple of alphabet values
        Q, c = beacon_layer(R, R, W, alphabet=tuple(A), loops=2)
        np.testing.assert_allclose(D, np.asarray(Q) * np.asarray(c)[None, :],
                                   rtol=1e-6)

    def test_more_loops_never_worse(self):
        _, R, W = make_layer(4, 64, 14, 3)
        A = alphabet(2.0)
        prev = -1.0
        for loops in (0, 1, 2, 4, 6):
            Q, _ = beacon_layer(R, R, W, alphabet=tuple(A), loops=loops)
            obj = min(
                ref.beacon_objective(R, R, W[:, j], np.asarray(Q)[:, j])
                for j in range(W.shape[1])
            )
            assert obj >= prev - 1e-5
            prev = obj


class TestBeaconKernelHypothesis:
    @given(
        seed=st.integers(0, 2**31 - 1),
        n=st.sampled_from([4, 8, 12, 24]),
        np_=st.sampled_from([1, 3, 5]),
        bits=st.sampled_from([1.58, 2.0, 2.58, 3.0, 4.0]),
        loops=st.integers(0, 4),
    )
    @settings(max_examples=20, deadline=None)
    def test_kernel_objective_ge_ref(self, seed, n, np_, bits, loops):
        """Sweep shapes/dtypes: kernel output must (a) live on the alphabet,
        (b) reach an objective within tolerance of the f64 oracle."""
        _, R, W = make_layer(seed, 4 * n, n, np_)
        A = alphabet(bits)
        Q, c = beacon_layer(R, R, W, alphabet=tuple(A), loops=loops)
        Q = np.asarray(Q)
        assert set(np.unique(Q)).issubset({np.float32(a) for a in A})
        for j in range(np_):
            obj_k = ref.beacon_objective(R, R, W[:, j], Q[:, j])
            q_ref, _ = ref.beacon_channel(R, R, W[:, j], A, loops)
            obj_r = ref.beacon_objective(R, R, W[:, j], q_ref)
            assert obj_k >= obj_r - 5e-3

    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_scale_fixed_point(self, seed):
        """Corollary 2.2: returned c satisfies c = ⟨Lw,L̃q⟩/||L̃q||²."""
        _, R, W = make_layer(seed, 32, 8, 2)
        A = alphabet(2.0)
        Q, c = beacon_layer(R, R, W, alphabet=tuple(A), loops=2)
        Q, c = np.asarray(Q, np.float64), np.asarray(c)
        for j in range(2):
            u = R.astype(np.float64) @ Q[:, j]
            y = R.astype(np.float64) @ W[:, j].astype(np.float64)
            den = float(u @ u)
            expect = float(y @ u) / den if den > 1e-12 else 0.0
            np.testing.assert_allclose(c[j], expect, rtol=1e-4, atol=1e-6)
