"""WTS1 tensor-bundle roundtrip + HLO lowering smoke tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import io as wio
from compile.aot import to_hlo_text
from compile.common import CONFIGS
from compile.kernels.beacon import beacon_layer_raw


class TestWts1:
    def test_roundtrip(self, tmp_path):
        p = str(tmp_path / "t.bin")
        tensors = [
            ("a", np.arange(6, dtype=np.float32).reshape(2, 3)),
            ("nested.name.w", np.ones((4,), dtype=np.float32)),
            ("scalar-ish", np.asarray([3.5], dtype=np.float32)),
        ]
        wio.save_tensors(p, tensors)
        out = wio.load_tensors(p)
        assert [n for n, _ in out] == [n for n, _ in tensors]
        for (_, a), (_, b) in zip(tensors, out):
            np.testing.assert_array_equal(a, b)

    def test_dict_loader(self, tmp_path):
        p = str(tmp_path / "t.bin")
        wio.save_tensors(p, [("x", np.zeros((2, 2), np.float32))])
        d = wio.load_tensor_dict(p)
        assert d["x"].shape == (2, 2)

    def test_bad_magic_rejected(self, tmp_path):
        p = str(tmp_path / "bad.bin")
        with open(p, "wb") as f:
            f.write(b"NOPE")
        with pytest.raises(AssertionError):
            wio.load_tensors(p)


class TestHloLowering:
    def test_plain_fn_lowers_to_text(self):
        f = lambda x, y: (jnp.matmul(x, y) + 1.0,)
        spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
        text = to_hlo_text(jax.jit(f).lower(spec, spec))
        assert "ENTRY" in text and "dot" in text

    def test_beacon_kernel_lowers_to_text(self):
        """The pallas kernel (interpret=True) must lower to plain HLO —
        no custom-calls the CPU PJRT client can't run."""
        n, np_ = 8, 3
        args = (
            jax.ShapeDtypeStruct((n, n), jnp.float32),
            jax.ShapeDtypeStruct((n, n), jnp.float32),
            jax.ShapeDtypeStruct((n, np_), jnp.float32),
            jax.ShapeDtypeStruct((16,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        )
        fn = lambda L, Lt, W, a, k: beacon_layer_raw(L, Lt, W, a, k)
        text = to_hlo_text(jax.jit(fn).lower(*args))
        assert "ENTRY" in text
        assert "custom-call" not in text.lower()
