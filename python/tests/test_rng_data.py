"""Golden + property tests for the shared RNG and synthetic dataset.

The golden values here are duplicated verbatim in
``rust/src/data/rng.rs`` / ``rust/src/data/synthetic.rs`` tests — they pin
the cross-language contract. Do not regenerate casually.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import data
from compile.common import CONFIGS, SplitMix64, combine, mix64

CFG = CONFIGS["tiny-sim"]


class TestSplitMix:
    def test_mix64_golden(self):
        assert mix64(0) == 0x0
        assert mix64(1) == 0x5692161D100B05E5
        assert mix64(0xDEADBEEF) == 0x4E062702EC929EEA

    def test_combine_golden(self):
        assert combine(1, 2) == 0xF2826F98653E9E57

    def test_stream_golden(self):
        s = SplitMix64(42)
        assert [s.next_u64() for _ in range(3)] == [
            0xBDD732262FEB6E95,
            0x28EFE333B266F103,
            0x47526757130F9F52,
        ]

    def test_f32_golden(self):
        s = SplitMix64(42)
        vals = [s.next_f32() for _ in range(4)]
        np.testing.assert_allclose(
            vals,
            [0.7415648698806763, 0.1599103808403015,
             0.27860110998153687, 0.34419065713882446],
            rtol=0, atol=0,
        )

    @given(st.integers(min_value=0, max_value=(1 << 64) - 1))
    @settings(max_examples=200, deadline=None)
    def test_f32_in_unit_interval(self, seed):
        s = SplitMix64(seed)
        for _ in range(8):
            v = s.next_f32()
            assert 0.0 <= v < 1.0

    @given(st.integers(min_value=0, max_value=(1 << 64) - 1),
           st.integers(min_value=0, max_value=(1 << 64) - 1))
    @settings(max_examples=100, deadline=None)
    def test_combine_order_sensitive(self, a, b):
        if a != b:
            assert combine(a, b) != combine(b, a) or a == b

    def test_mix64_bijective_sample(self):
        # distinct inputs -> distinct outputs (injectivity spot check)
        outs = {mix64(i) for i in range(10_000)}
        assert len(outs) == 10_000


class TestDataset:
    def test_deterministic(self):
        a, la = data.generate(CFG, 2, 8)
        b, lb = data.generate(CFG, 2, 8)
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(la, lb)

    def test_golden_sample(self):
        imgs, labels = data.generate(CFG, 2, 3)
        np.testing.assert_allclose(
            imgs[0].ravel()[:5],
            [0.5070157051086426, 0.16118144989013672, 0.40140822529792786,
             0.29602834582328796, 0.2174665927886963],
            rtol=0, atol=0,
        )
        assert labels.tolist() == [0, 1, 2]
        assert abs(float(imgs.sum()) - 1109.60693359375) < 1e-3

    def test_templates_shared_across_splits(self):
        t = data.class_template(CFG, 3)
        assert t.shape == (CFG.image, CFG.image, CFG.channels)
        # template does not depend on any split seed by construction
        np.testing.assert_array_equal(t, data.class_template(CFG, 3))

    def test_range_and_labels(self):
        imgs, labels = data.generate(CFG, 7, 25)
        assert imgs.min() >= 0.0 and imgs.max() <= 1.0
        assert (labels == np.arange(25) % CFG.num_classes).all()

    def test_splits_differ(self):
        a, _ = data.generate(CFG, 2, 4)
        b, _ = data.generate(CFG, 3, 4)
        assert not np.allclose(a, b)

    def test_save_load_roundtrip(self, tmp_path):
        imgs, labels = data.generate(CFG, 2, 5)
        p = str(tmp_path / "d.bin")
        data.save_dataset(p, imgs, labels)
        i2, l2 = data.load_dataset(p)
        np.testing.assert_array_equal(imgs, i2)
        np.testing.assert_array_equal(labels, l2)
