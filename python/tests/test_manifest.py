"""Manifest contract tests: the JSON the Rust coordinator consumes must
stay in lock-step with `common.py`. Runs against the built artifacts when
present; otherwise builds a manifest dict in-memory via aot helpers."""

import json
import os

import pytest

from compile.aot import quant_layer_shapes, ALPH_PAD
from compile.common import CONFIGS, param_spec, quantizable_layers

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestShapes:
    def test_quant_layer_shapes_unique(self):
        cfg = CONFIGS["tiny-sim"]
        shapes = quant_layer_shapes(cfg)
        assert len(shapes) == len(set(shapes))
        assert (64, 192) in shapes and (128, 64) in shapes

    def test_alph_pad_covers_all_alphabets(self):
        from compile.common import BIT_WIDTHS, alphabet

        for b in BIT_WIDTHS:
            assert len(alphabet(b)) <= ALPH_PAD


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest__tiny-sim.json")),
    reason="artifacts not built",
)
class TestBuiltManifest:
    def setup_method(self):
        with open(os.path.join(ART, "manifest__tiny-sim.json")) as f:
            self.m = json.load(f)

    def test_params_match_spec(self):
        cfg = CONFIGS["tiny-sim"]
        spec = [[n, list(sh)] for n, sh in param_spec(cfg)]
        assert self.m["params"] == spec

    def test_quantizable_match(self):
        assert self.m["quantizable"] == quantizable_layers(CONFIGS["tiny-sim"])

    def test_artifact_files_exist(self):
        a = self.m["artifacts"]
        for key in ("weights", "calib", "eval", "vit_logits",
                    "collect_acts", "ln_tune_step"):
            assert os.path.exists(os.path.join(ART, a[key])), key
        for path in a["beacon_layer"].values():
            assert os.path.exists(os.path.join(ART, path))

    def test_beacon_layer_covers_quantizable(self):
        cfg = CONFIGS["tiny-sim"]
        spec = dict(param_spec(cfg))
        for name in self.m["quantizable"]:
            n, np_ = spec[name]
            assert f"{n}x{np_}" in self.m["artifacts"]["beacon_layer"], name
