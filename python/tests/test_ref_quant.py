"""Properties of the numpy oracles: the paper's propositions, baselines'
sanity, and the orderings the evaluation section reports."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.common import alphabet
from compile.kernels import ref


def make_case(seed, m=64, n=12, cond=0.3):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(m, n)) @ (np.eye(n) + cond * rng.normal(size=(n, n)))
    w = rng.normal(size=(n,)) * 0.3
    return X.astype(np.float32), w.astype(np.float32)


class TestAlphabet:
    def test_grids(self):
        assert alphabet(1.58) == [-1.0, 0.0, 1.0]
        assert alphabet(2.0) == [-1.5, -0.5, 0.5, 1.5]
        assert alphabet(2.58) == [-2.5, -1.5, -0.5, 0.5, 1.5, 2.5]
        assert len(alphabet(3.0)) == 8
        assert len(alphabet(4.0)) == 16

    @pytest.mark.parametrize("bits", [1.58, 2.0, 2.58, 3.0, 4.0])
    def test_symmetric(self, bits):
        a = np.asarray(alphabet(bits))
        np.testing.assert_allclose(sorted(a), sorted(-a))


class TestBeaconChannel:
    @pytest.mark.parametrize("bits", [1.58, 2.0, 3.0])
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_objective_monotone_in_loops(self, bits, seed):
        """Prop 3.1: e_l is non-decreasing in the sweep count."""
        X, w = make_case(seed)
        _, R = np.linalg.qr(X)
        A = alphabet(bits)
        objs = []
        for loops in range(0, 6):
            q, _ = ref.beacon_channel(R, R, w, A, loops)
            objs.append(ref.beacon_objective(R, R, w, q))
        assert all(b >= a - 1e-12 for a, b in zip(objs, objs[1:])), objs

    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_coordinatewise_local_optimum(self, seed):
        """After convergence no single-coordinate change improves cos∠."""
        X, w = make_case(seed, n=8)
        _, R = np.linalg.qr(X)
        A = alphabet(2.0)
        q, _ = ref.beacon_channel(R, R, w, A, loops=12)
        base = ref.beacon_objective(R, R, w, q)
        for t in range(len(w)):
            for p in A:
                q2 = q.copy()
                q2[t] = p
                assert ref.beacon_objective(R, R, w, q2) <= base + 1e-9

    @pytest.mark.parametrize("seed", [0, 5])
    def test_scale_is_least_squares_optimal(self, seed):
        """Prop 2.1: perturbing c away from the closed form increases
        ||Xw − cXq||."""
        X, w = make_case(seed)
        _, R = np.linalg.qr(X)
        A = alphabet(2.0)
        q, c = ref.beacon_channel(R, R, w, A, loops=4)

        def err(cc):
            return np.linalg.norm(R @ w - cc * (R @ q))

        e0 = err(c)
        for dc in (-0.1, -0.01, 0.01, 0.1):
            assert err(float(c) * (1 + dc)) >= e0 - 1e-9

    def test_ternary_small_exhaustive(self):
        """N=4 ternary: the converged q must match the best exhaustively
        enumerated single-coordinate-stable point's objective within the
        greedy's reach (and never exceed the global optimum)."""
        X, w = make_case(7, m=32, n=4)
        _, R = np.linalg.qr(X)
        A = alphabet(1.58)
        q, _ = ref.beacon_channel(R, R, w, A, loops=10)
        got = ref.beacon_objective(R, R, w, q)
        best = -1.0
        from itertools import product
        for cand in product(A, repeat=4):
            best = max(best, ref.beacon_objective(R, R, w, np.asarray(cand)))
        assert got <= best + 1e-12
        assert got >= 0.8 * best  # greedy+sweeps should be near-global here

    def test_values_in_alphabet(self):
        X, w = make_case(3)
        _, R = np.linalg.qr(X)
        for bits in (1.58, 2.0, 4.0):
            A = alphabet(bits)
            q, _ = ref.beacon_channel(R, R, w, A, loops=3)
            assert set(np.unique(q)).issubset(set(np.float32(A)))

    def test_zero_weight_channel(self):
        X, _ = make_case(0)
        _, R = np.linalg.qr(X)
        q, c = ref.beacon_channel(R, R, np.zeros(12), alphabet(2.0), 3)
        # degenerate target: scale must be finite
        assert np.isfinite(c)

    def test_sign_symmetry(self):
        """Negating w should negate the optimal scaled vector (alphabet is
        symmetric): reconstruction errors must match."""
        X, w = make_case(11)
        _, R = np.linalg.qr(X)
        A = alphabet(2.0)
        q1, c1 = ref.beacon_channel(R, R, w, A, 4)
        q2, c2 = ref.beacon_channel(R, R, -w, A, 4)
        e1 = np.linalg.norm(R @ w - c1 * (R @ q1))
        e2 = np.linalg.norm(R @ (-w) - c2 * (R @ q2))
        np.testing.assert_allclose(e1, e2, rtol=1e-5, atol=1e-7)


class TestQRReduction:
    @pytest.mark.parametrize("seed", [0, 1])
    def test_rotation_invariance(self, seed):
        """cos∠(Xw, Xq) == cos∠(Rw, Rq) — the memory-efficient claim."""
        X, w = make_case(seed)
        _, R = np.linalg.qr(X)
        rng = np.random.default_rng(seed + 100)
        q = rng.choice(alphabet(2.0), size=w.shape)
        a = ref.beacon_objective(X, X, w, q)
        b = ref.beacon_objective(R, R, w, q)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)

    def test_ec_reduction_identity(self):
        """⟨Xw, X̃q⟩/||X̃q|| == ⟨UᵀXw, Rq⟩/||Rq|| (eq. 5)."""
        X, w = make_case(0)
        Xt = X + 0.05 * np.random.default_rng(1).normal(size=X.shape)
        U, R = np.linalg.qr(Xt)
        L = U.T @ X
        q = np.random.default_rng(2).choice(alphabet(2.0), size=w.shape)
        lhs = float((X @ w) @ (Xt @ q)) / np.linalg.norm(Xt @ q)
        rhs = float((L @ w) @ (R @ q)) / np.linalg.norm(R @ q)
        np.testing.assert_allclose(lhs, rhs, rtol=1e-4)


class TestLayerAndBaselines:
    def setup_method(self):
        rng = np.random.default_rng(42)
        self.X = (rng.normal(size=(128, 16)) @
                  (np.eye(16) + 0.2 * rng.normal(size=(16, 16)))).astype(np.float32)
        self.W = (rng.normal(size=(16, 8)) * 0.2).astype(np.float32)

    def test_rtn_idempotent_on_grid(self):
        q = ref.rtn_channel(self.W[:, 0], 3.0)
        np.testing.assert_allclose(ref.rtn_channel(q, 3.0), q, atol=1e-6)

    def test_rtn_preserves_extremes(self):
        w = self.W[:, 0]
        q = ref.rtn_channel(w, 2.0)
        assert abs(float(q.min()) - float(w.min())) < 1e-5
        assert abs(float(q.max()) - float(w.max())) < 1e-5

    @pytest.mark.parametrize("bits", [2.0, 3.0, 4.0])
    def test_gptq_beats_rtn(self, bits):
        rtn = np.stack(
            [ref.rtn_channel(self.W[:, j], bits) for j in range(8)], axis=1
        )
        gq = ref.gptq_layer(self.X, self.W, bits)
        assert (ref.layer_recon_error(self.X, self.W, gq)
                < ref.layer_recon_error(self.X, self.W, rtn) + 1e-9)

    @pytest.mark.parametrize("bits", [2.0, 3.0])
    def test_comq_beats_rtn(self, bits):
        rtn = np.stack(
            [ref.rtn_channel(self.W[:, j], bits) for j in range(8)], axis=1
        )
        cq = ref.comq_layer(self.X, self.W, bits)
        assert (ref.layer_recon_error(self.X, self.W, cq)
                < ref.layer_recon_error(self.X, self.W, rtn) + 1e-9)

    def test_beacon_best_at_2bit(self):
        """The paper's headline ordering at 2-bit."""
        bits = 2.0
        gq = ref.gptq_layer(self.X, self.W, bits)
        bq = ref.beacon_layer(self.X, self.X, self.W, alphabet(bits), 4)
        assert (ref.layer_recon_error(self.X, self.W, bq)
                < ref.layer_recon_error(self.X, self.W, gq))

    def test_centering_helps_offset_weights(self):
        """Asymmetric weights: centering must reduce reconstruction error."""
        W = self.W + 0.3  # strong common offset
        A = alphabet(2.0)
        plain = ref.beacon_layer(self.X, self.X, W, A, 4, centering=False)
        cent = ref.beacon_layer(self.X, self.X, W, A, 4, centering=True)
        assert (ref.layer_recon_error(self.X, W, cent)
                < ref.layer_recon_error(self.X, W, plain))

    def test_ec_accounts_for_input_mismatch(self):
        """With X̃ ≠ X, EC should reconstruct XW from X̃Q better than
        ignoring the mismatch."""
        rng = np.random.default_rng(9)
        Xt = self.X + 0.15 * rng.normal(size=self.X.shape).astype(np.float32)
        A = alphabet(2.0)
        ec = ref.beacon_layer(self.X, Xt, self.W, A, 4)
        no_ec = ref.beacon_layer(self.X, self.X, self.W, A, 4)

        def err(Q):
            num = np.linalg.norm(self.X @ self.W - Xt @ Q)
            return num / np.linalg.norm(self.X @ self.W)

        assert err(ec) < err(no_ec) + 1e-9

    @given(st.integers(min_value=0, max_value=10_000))
    @settings(max_examples=25, deadline=None)
    def test_gptq_output_on_grid(self, seed):
        rng = np.random.default_rng(seed)
        W = (rng.normal(size=(8, 4)) * 0.3).astype(np.float32)
        X = rng.normal(size=(32, 8)).astype(np.float32)
        Q = ref.gptq_layer(X, W, 2.0)
        # every output column lives on a 4-level grid
        for j in range(4):
            assert len(np.unique(np.round(Q[:, j], 5))) <= 4
