"""Shared build-time definitions: model configs, parameter ordering, alphabets,
and the deterministic RNG used for the synthetic dataset.

Everything here has an exact Rust mirror (``rust/src/model/spec.rs``,
``rust/src/quant/alphabet.rs``, ``rust/src/data/rng.rs``); the two sides are
cross-checked by tests on both sides. Keep the constants in sync.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

MASK64 = (1 << 64) - 1
GOLDEN = 0x9E3779B97F4A7C15


# --------------------------------------------------------------------------
# splitmix64 — the shared deterministic RNG (same constants as Rust side).
# --------------------------------------------------------------------------
def mix64(z: int) -> int:
    z &= MASK64
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
    return (z ^ (z >> 31)) & MASK64


def combine(a: int, b: int) -> int:
    """Seed-combining hash: order-sensitive, avalanching."""
    return mix64((a & MASK64) ^ mix64((b + GOLDEN) & MASK64))


class SplitMix64:
    """Counter-based splitmix64 stream."""

    def __init__(self, seed: int):
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + GOLDEN) & MASK64
        return mix64(self.state)

    def next_f32(self) -> float:
        """Uniform in [0, 1) with 24 bits of entropy (exact in f32)."""
        return (self.next_u64() >> 40) / float(1 << 24)

    def fill_f32(self, n: int) -> List[float]:
        return [self.next_f32() for _ in range(n)]


# --------------------------------------------------------------------------
# Quantization alphabets.
# --------------------------------------------------------------------------
def alphabet(bits: float) -> List[float]:
    """The unscaled symmetric grid A used by Beacon.

    * integer b >= 2: mid-rise grid {-2^{b-1}+0.5, ..., -0.5, 0.5, ..., 2^{b-1}-0.5}
    * 1.58 ("ternary"): {-1, 0, 1}
    * 2.58: {-2.5,...,2.5} union {0}? No — the paper's 2.58-bit is log2(6):
      the 6-element grid {-2.5,-1.5,-0.5,0.5,1.5,2.5}.
    """
    if abs(bits - 1.58) < 1e-9:
        return [-1.0, 0.0, 1.0]
    if abs(bits - 2.58) < 1e-9:
        return [-2.5, -1.5, -0.5, 0.5, 1.5, 2.5]
    b = int(round(bits))
    assert abs(bits - b) < 1e-9 and b >= 1, f"unsupported bit width {bits}"
    half = 1 << (b - 1)
    return [(-half + 0.5) + k for k in range(2 * half)]


BIT_WIDTHS = [1.58, 2.0, 2.58, 3.0, 4.0]


# --------------------------------------------------------------------------
# Model configuration + parameter ordering contract.
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ViTConfig:
    name: str
    image: int = 16          # image is image x image pixels
    channels: int = 3
    patch: int = 4
    d_model: int = 64
    depth: int = 4
    heads: int = 4
    mlp_ratio: int = 2
    num_classes: int = 10

    @property
    def tokens(self) -> int:
        return (self.image // self.patch) ** 2 + 1  # patches + cls

    @property
    def patch_dim(self) -> int:
        return self.patch * self.patch * self.channels

    @property
    def d_mlp(self) -> int:
        return self.d_model * self.mlp_ratio


CONFIGS = {
    # default build: small enough to train + quantize + eval on one CPU core
    "tiny-sim": ViTConfig(name="tiny-sim", d_model=64, depth=4, heads=4),
    # a wider variant for sweeps / perf work
    "small-sim": ViTConfig(name="small-sim", d_model=128, depth=6, heads=4),
    # DeiT-B geometry (for VMEM estimates and config-completeness; too big
    # to run end-to-end on this single-core CPU testbed)
    "deit-b": ViTConfig(
        name="deit-b", image=224, channels=3, patch=16,
        d_model=768, depth=12, heads=12, mlp_ratio=4, num_classes=1000,
    ),
}


def param_spec(cfg: ViTConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Flat (name, shape) list — THE ordering contract with the Rust side."""
    d, f, p = cfg.d_model, cfg.d_mlp, cfg.patch_dim
    spec: List[Tuple[str, Tuple[int, ...]]] = [
        ("patch_embed.w", (p, d)),
        ("patch_embed.b", (d,)),
        ("cls_token", (1, d)),
        ("pos_embed", (cfg.tokens, d)),
    ]
    for i in range(cfg.depth):
        spec += [
            (f"blocks.{i}.ln1.g", (d,)),
            (f"blocks.{i}.ln1.b", (d,)),
            (f"blocks.{i}.qkv.w", (d, 3 * d)),
            (f"blocks.{i}.qkv.b", (3 * d,)),
            (f"blocks.{i}.proj.w", (d, d)),
            (f"blocks.{i}.proj.b", (d,)),
            (f"blocks.{i}.ln2.g", (d,)),
            (f"blocks.{i}.ln2.b", (d,)),
            (f"blocks.{i}.fc1.w", (d, f)),
            (f"blocks.{i}.fc1.b", (f,)),
            (f"blocks.{i}.fc2.w", (f, d)),
            (f"blocks.{i}.fc2.b", (d,)),
        ]
    spec += [
        ("ln_f.g", (d,)),
        ("ln_f.b", (d,)),
        ("head.w", (d, cfg.num_classes)),
        ("head.b", (cfg.num_classes,)),
    ]
    return spec


def quantizable_layers(cfg: ViTConfig) -> List[str]:
    """Names of the weight matrices Beacon quantizes, in pipeline order.

    Patch embedding and classifier head stay full precision by default
    (standard PTQ practice for small models; configurable on the Rust side).
    """
    names = []
    for i in range(cfg.depth):
        names += [
            f"blocks.{i}.qkv.w",
            f"blocks.{i}.proj.w",
            f"blocks.{i}.fc1.w",
            f"blocks.{i}.fc2.w",
        ]
    return names


def ln_param_names(cfg: ViTConfig) -> List[str]:
    """LayerNorm parameters tuned by the optional LN-tuning pass."""
    names = []
    for i in range(cfg.depth):
        names += [
            f"blocks.{i}.ln1.g", f"blocks.{i}.ln1.b",
            f"blocks.{i}.ln2.g", f"blocks.{i}.ln2.b",
        ]
    names += ["ln_f.g", "ln_f.b"]
    return names
