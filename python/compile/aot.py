"""AOT build: train the FP model, generate datasets, lower every L2 graph to
HLO *text* artifacts, and write the manifest the Rust coordinator consumes.

HLO text (NOT ``lowered.compile().serialize()``) is the interchange format:
jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which the
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Run via ``make artifacts`` (idempotent — skips work whose outputs exist and
whose inputs are older).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as data_mod
from .common import CONFIGS, ViTConfig, alphabet, param_spec, quantizable_layers
from .io import save_tensors
from .kernels.beacon import beacon_layer_raw
from .model import collect_acts_fn, forward, ln_tune_step_fn, logits_fn
from .train import train

# Alphabet inputs are padded to this length by repeating the max element;
# padding is inert because the argmax tie-break is first-occurrence.
ALPH_PAD = 16

CALIB_SEED, EVAL_SEED = 2, 3


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn, example_args, path: str) -> None:
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)/1e3:.0f} kB)")


def spec_of(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def quant_layer_shapes(cfg: ViTConfig):
    """Unique (N, N') shapes among quantizable weight matrices."""
    spec = dict(param_spec(cfg))
    shapes = []
    for name in quantizable_layers(cfg):
        sh = spec[name]
        if sh not in shapes:
            shapes.append(sh)
    return shapes


def build(cfg: ViTConfig, out_dir: str, train_steps: int, calib_count: int,
          eval_count: int, ln_batch: int, force: bool = False) -> None:
    os.makedirs(out_dir, exist_ok=True)
    tag = cfg.name

    def path(stem: str) -> str:
        return os.path.join(out_dir, f"{stem}__{tag}")

    # ---- datasets ---------------------------------------------------------
    calib_path = path("calib") + ".bin"
    eval_path = path("eval") + ".bin"
    if force or not os.path.exists(calib_path):
        imgs, labels = data_mod.generate(cfg, CALIB_SEED, calib_count)
        data_mod.save_dataset(calib_path, imgs, labels)
        print(f"  wrote {calib_path} ({calib_count} images)")
    if force or not os.path.exists(eval_path):
        imgs, labels = data_mod.generate(cfg, EVAL_SEED, eval_count)
        data_mod.save_dataset(eval_path, imgs, labels)
        print(f"  wrote {eval_path} ({eval_count} images)")

    # ---- trained FP weights ----------------------------------------------
    weights_path = path("model_weights") + ".bin"
    if force or not os.path.exists(weights_path):
        print(f"  training {tag} for {train_steps} steps ...")
        params = train(cfg, steps=train_steps)
        save_tensors(weights_path, list(zip([n for n, _ in param_spec(cfg)], params)))
        print(f"  wrote {weights_path}")

    # ---- HLO graphs -------------------------------------------------------
    pspecs = [spec_of(sh) for _, sh in param_spec(cfg)]

    logits_hlo = path("vit_logits") + ".hlo.txt"
    if force or not os.path.exists(logits_hlo):
        img_spec = spec_of((eval_batch_size(cfg), cfg.image, cfg.image, cfg.channels))
        lower_to_file(logits_fn(cfg), (*pspecs, img_spec), logits_hlo)

    acts_hlo = path("collect_acts") + ".hlo.txt"
    if force or not os.path.exists(acts_hlo):
        img_spec = spec_of((calib_count, cfg.image, cfg.image, cfg.channels))
        lower_to_file(collect_acts_fn(cfg), (*pspecs, img_spec), acts_hlo)

    ln_hlo = path("ln_tune_step") + ".hlo.txt"
    if force or not os.path.exists(ln_hlo):
        step, _ = ln_tune_step_fn(cfg)
        img_spec = spec_of((ln_batch, cfg.image, cfg.image, cfg.channels))
        teach_spec = spec_of((ln_batch, cfg.num_classes))
        lr_spec = spec_of(())
        lower_to_file(step, (*pspecs, img_spec, teach_spec, lr_spec), ln_hlo)

    beacon_paths = {}
    for (n, np_) in quant_layer_shapes(cfg):
        stem = path(f"beacon_layer_{n}x{np_}") + ".hlo.txt"
        beacon_paths[f"{n}x{np_}"] = os.path.basename(stem)
        if force or not os.path.exists(stem):
            fn = lambda L, Lt, W, alph, loops: beacon_layer_raw(L, Lt, W, alph, loops)
            args = (
                spec_of((n, n)), spec_of((n, n)), spec_of((n, np_)),
                spec_of((ALPH_PAD,)), spec_of((1,), jnp.int32),
            )
            lower_to_file(fn, args, stem)

    # ---- manifest ---------------------------------------------------------
    manifest = {
        "config": {
            "name": cfg.name, "image": cfg.image, "channels": cfg.channels,
            "patch": cfg.patch, "d_model": cfg.d_model, "depth": cfg.depth,
            "heads": cfg.heads, "mlp_ratio": cfg.mlp_ratio,
            "num_classes": cfg.num_classes, "tokens": cfg.tokens,
        },
        "alph_pad": ALPH_PAD,
        "eval_batch": eval_batch_size(cfg),
        "calib_count": calib_count,
        "eval_count": eval_count,
        "ln_batch": ln_batch,
        "params": [[n, list(sh)] for n, sh in param_spec(cfg)],
        "quantizable": quantizable_layers(cfg),
        "artifacts": {
            "weights": os.path.basename(weights_path),
            "calib": os.path.basename(calib_path),
            "eval": os.path.basename(eval_path),
            "vit_logits": os.path.basename(logits_hlo),
            "collect_acts": os.path.basename(acts_hlo),
            "ln_tune_step": os.path.basename(ln_hlo),
            "beacon_layer": beacon_paths,
        },
    }
    mpath = path("manifest") + ".json"
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote {mpath}")


def eval_batch_size(cfg: ViTConfig) -> int:
    return 128


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--config", default="tiny-sim", choices=sorted(CONFIGS))
    ap.add_argument("--train-steps", type=int, default=600)
    ap.add_argument("--calib-count", type=int, default=128)
    ap.add_argument("--eval-count", type=int, default=1024)
    ap.add_argument("--ln-batch", type=int, default=64)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    cfg = CONFIGS[args.config]
    t0 = time.time()
    print(f"[aot] building artifacts for {cfg.name} -> {args.out}")
    build(cfg, args.out, args.train_steps, args.calib_count, args.eval_count,
          args.ln_batch, force=args.force)
    print(f"[aot] done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
