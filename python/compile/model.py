"""L2: the JAX ViT (DeiT-family) compute graphs.

Everything is written over a *flat tuple of parameters* in the order given by
``common.param_spec`` — that ordering is the ABI with the Rust coordinator,
which feeds the same flat list of literals to the AOT-compiled executables.

Graphs exported by aot.py:
  * ``logits(params, images)``            — eval forward
  * ``collect_acts(params, images)``      — forward + inputs to every
                                            quantizable linear (GPTQ/Beacon
                                            calibration matrices)
  * ``ln_tune_step(...)``                 — one SGD distillation step on the
                                            LayerNorm parameters only
"""

from __future__ import annotations

from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .common import SplitMix64, ViTConfig, combine, ln_param_names, param_spec


def params_to_dict(cfg: ViTConfig, flat: Sequence[jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    spec = param_spec(cfg)
    assert len(flat) == len(spec), (len(flat), len(spec))
    out = {}
    for (name, shape), arr in zip(spec, flat):
        assert tuple(arr.shape) == tuple(shape), (name, arr.shape, shape)
        out[name] = arr
    return out


def dict_to_params(cfg: ViTConfig, d: Dict[str, jnp.ndarray]) -> List[jnp.ndarray]:
    return [d[name] for name, _ in param_spec(cfg)]


def init_params(cfg: ViTConfig, seed: int = 0) -> List[np.ndarray]:
    """Deterministic init (sum-of-uniforms ~ bounded normal-ish)."""
    out = []
    for idx, (name, shape) in enumerate(param_spec(cfg)):
        rng = SplitMix64(combine(combine(seed, 0x1717), idx))
        n = int(np.prod(shape))
        if name.endswith(".b") or name.endswith(".g"):
            arr = (
                np.ones(n, dtype=np.float32)
                if name.endswith(".g")
                else np.zeros(n, dtype=np.float32)
            )
        else:
            fan_in = shape[0] if len(shape) > 1 else n
            std = (2.0 / float(fan_in)) ** 0.5 * 0.5
            u = np.asarray(rng.fill_f32(2 * n), dtype=np.float32)
            # sum of two uniforms, centered: triangular, bounded, ~N(0, std)
            arr = ((u[:n] + u[n:]) - 1.0) * (std * (6.0 ** 0.5) / 2.0)
        out.append(arr.reshape(shape).astype(np.float32))
    return out


# --------------------------------------------------------------------------
# Forward pass
# --------------------------------------------------------------------------
def _layer_norm(x, g, b, eps=1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _patchify(cfg: ViTConfig, images):
    """images[B,H,W,C] -> patches[B, P, patch*patch*C]."""
    B = images.shape[0]
    p, g = cfg.patch, cfg.image // cfg.patch
    x = images.reshape(B, g, p, g, p, cfg.channels)
    x = x.transpose(0, 1, 3, 2, 4, 5)  # B, g, g, p, p, C
    return x.reshape(B, g * g, p * p * cfg.channels)


def _attention(cfg: ViTConfig, x, qkv_w, qkv_b, proj_w, proj_b, collect):
    B, T, d = x.shape
    h = cfg.heads
    hd = d // h
    collect.append(x)  # input to qkv
    qkv = x @ qkv_w + qkv_b  # [B,T,3d]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(B, T, h, hd).transpose(0, 2, 1, 3)
    k = k.reshape(B, T, h, hd).transpose(0, 2, 1, 3)
    v = v.reshape(B, T, h, hd).transpose(0, 2, 1, 3)
    att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(jnp.float32(hd))
    att = jax.nn.softmax(att, axis=-1)
    y = (att @ v).transpose(0, 2, 1, 3).reshape(B, T, d)
    collect.append(y)  # input to proj
    return y @ proj_w + proj_b


def _block(cfg: ViTConfig, x, p: Dict[str, jnp.ndarray], i: int, collect):
    pre = f"blocks.{i}."
    y = _layer_norm(x, p[pre + "ln1.g"], p[pre + "ln1.b"])
    x = x + _attention(
        cfg, y, p[pre + "qkv.w"], p[pre + "qkv.b"],
        p[pre + "proj.w"], p[pre + "proj.b"], collect,
    )
    y = _layer_norm(x, p[pre + "ln2.g"], p[pre + "ln2.b"])
    collect.append(y)  # input to fc1
    h = jax.nn.gelu(y @ p[pre + "fc1.w"] + p[pre + "fc1.b"], approximate=True)
    collect.append(h)  # input to fc2
    x = x + h @ p[pre + "fc2.w"] + p[pre + "fc2.b"]
    return x


def forward(cfg: ViTConfig, flat_params: Sequence[jnp.ndarray], images,
            want_acts: bool = False):
    """Returns logits[B,K] and, if want_acts, the list of inputs to every
    quantizable linear, each flattened to [B*T, N] — order matches
    ``common.quantizable_layers``."""
    p = params_to_dict(cfg, flat_params)
    B = images.shape[0]
    collect: List[jnp.ndarray] = []
    x = _patchify(cfg, images) @ p["patch_embed.w"] + p["patch_embed.b"]
    cls = jnp.broadcast_to(p["cls_token"], (B, 1, cfg.d_model))
    x = jnp.concatenate([cls, x], axis=1) + p["pos_embed"]
    for i in range(cfg.depth):
        x = _block(cfg, x, p, i, collect)
    x = _layer_norm(x, p["ln_f.g"], p["ln_f.b"])
    logits = x[:, 0, :] @ p["head.w"] + p["head.b"]
    if not want_acts:
        return logits
    acts = [a.reshape(-1, a.shape[-1]) for a in collect]
    return logits, acts


def logits_fn(cfg: ViTConfig):
    def f(*args):
        *params, images = args
        return (forward(cfg, params, images),)

    return f


def collect_acts_fn(cfg: ViTConfig):
    def f(*args):
        *params, images = args
        logits, acts = forward(cfg, params, images, want_acts=True)
        return (logits, *acts)

    return f


# --------------------------------------------------------------------------
# LN tuning (distillation on LayerNorm params only) — paper §3 "Normalization
# Tuning". One plain-SGD step; the Rust coordinator drives the epoch loop.
# --------------------------------------------------------------------------
def ln_tune_step_fn(cfg: ViTConfig):
    spec = param_spec(cfg)
    ln_set = set(ln_param_names(cfg))
    ln_idx = [i for i, (n, _) in enumerate(spec) if n in ln_set]

    def loss(ln_params, params, images, teacher_logits):
        full = list(params)
        for j, i in enumerate(ln_idx):
            full[i] = ln_params[j]
        student = forward(cfg, full, images)
        return jnp.mean(jnp.square(student - teacher_logits))

    def step(*args):
        *params, images, teacher_logits, lr = args
        ln_params = [params[i] for i in ln_idx]
        l, grads = jax.value_and_grad(loss)(
            ln_params, list(params), images, teacher_logits
        )
        new = [p - lr * g for p, g in zip(ln_params, grads)]
        return (l, *new)

    return step, ln_idx
