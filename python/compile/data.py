"""Synthetic 'structured blobs' classification dataset.

Deterministic stand-in for ILSVRC-2012 (see DESIGN.md §3): each class k has a
fixed random template image T_k; a sample is a convex blend of its class
template and fresh noise plus a brightness jitter. The generator is exactly
mirrored in ``rust/src/data/synthetic.rs`` (same splitmix64 constants, same
draw order) and cross-checked by golden tests on both sides.

Seeds: train=1, calib=2, eval=3 (DESIGN.md §7).
"""

from __future__ import annotations

import numpy as np

from .common import SplitMix64, ViTConfig, combine

TEMPLATE_TAG = 0x7E3A17E5
SAMPLE_TAG = 0x5EED


def class_template(cfg: ViTConfig, k: int) -> np.ndarray:
    """Class templates are *split-independent*: the same template is shared
    by the train/calib/eval splits (only the per-sample noise differs)."""
    rng = SplitMix64(combine(TEMPLATE_TAG, k))
    n = cfg.image * cfg.image * cfg.channels
    return np.asarray(rng.fill_f32(n), dtype=np.float32).reshape(
        cfg.image, cfg.image, cfg.channels
    )


def sample(cfg: ViTConfig, seed: int, i: int, templates: np.ndarray) -> tuple:
    """Returns (image[H,W,C] f32 in [0,1], label)."""
    label = i % cfg.num_classes
    rng = SplitMix64(combine(combine(seed, SAMPLE_TAG), i))
    # Blend strength is deliberately weak so the FP model lands well below
    # 100% and low-bit quantization produces a visible accuracy cliff
    # (mirrors DeiT-B's 81.74% ceiling in spirit).
    alpha = 0.16 + 0.14 * rng.next_f32()
    brightness = (rng.next_f32() - 0.5) * 0.2
    n = cfg.image * cfg.image * cfg.channels
    noise = np.asarray(rng.fill_f32(n), dtype=np.float32).reshape(
        cfg.image, cfg.image, cfg.channels
    )
    img = alpha * templates[label] + (1.0 - alpha) * noise + brightness
    return np.clip(img, 0.0, 1.0).astype(np.float32), label


def generate(cfg: ViTConfig, seed: int, count: int) -> tuple:
    """Returns (images[count,H,W,C] f32, labels[count] i32)."""
    templates = np.stack(
        [class_template(cfg, k) for k in range(cfg.num_classes)]
    )
    images = np.empty(
        (count, cfg.image, cfg.image, cfg.channels), dtype=np.float32
    )
    labels = np.empty((count,), dtype=np.int32)
    for i in range(count):
        images[i], labels[i] = sample(cfg, seed, i, templates)
    return images, labels


def save_dataset(path: str, images: np.ndarray, labels: np.ndarray) -> None:
    """Flat little-endian binary, mirrored by rust/src/data/store.rs.

    Layout: magic 'DSET' | u32 count | u32 h | u32 w | u32 c |
            images f32le (count*h*w*c) | labels i32le (count)
    """
    with open(path, "wb") as f:
        f.write(b"DSET")
        n, h, w, c = images.shape
        np.asarray([n, h, w, c], dtype=np.uint32).tofile(f)
        images.astype("<f4").tofile(f)
        labels.astype("<i4").tofile(f)


def load_dataset(path: str) -> tuple:
    with open(path, "rb") as f:
        assert f.read(4) == b"DSET"
        n, h, w, c = np.fromfile(f, dtype=np.uint32, count=4)
        images = np.fromfile(f, dtype="<f4", count=n * h * w * c).reshape(
            n, h, w, c
        )
        labels = np.fromfile(f, dtype="<i4", count=n)
    return images, labels
