"""Pure-numpy correctness oracles for every quantization algorithm.

These are the ground truth the Pallas kernel (``beacon.py``), the L2 graphs,
and the Rust implementations are all validated against. Written for clarity,
not speed — they follow the paper's notation line by line.

Conventions (paper §1–§3):
  * a layer has weights W[N, N']; each *channel* is a column w ∈ R^N
  * X[m, N]  — calibration inputs from the full-precision model
  * X̃[m, N] — inputs from the partially quantized model (error correction)
  * memory-efficient form: X̃ = U R  (QR), L = UᵀX, L̃ = R — both N×N
  * alphabet A is symmetric about 0 (``common.alphabet``)

Tie-breaking contract (mirrored in Rust + Pallas): candidates are scanned in
ascending alphabet order and a candidate replaces the incumbent only on a
strictly greater score; a zero-denominator candidate scores -inf.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np

EPS = 1e-12


def argmax_candidate(y, u, col, alphabet) -> float:
    """argmax_{p in A} cos∠(y, u + col*p) via the 5-scalar expansion."""
    a = float(y @ u)
    b = float(y @ col)
    cc = float(u @ u)
    d = float(u @ col)
    e = float(col @ col)
    if cc <= EPS:
        # degenerate u = 0: every same-sign candidate has the same cosine.
        # Deterministic rule (shared with the Pallas kernel): take the
        # alphabet element nearest the least-squares coefficient b/e,
        # excluding candidates that would leave the vector zero (p = 0),
        # which have an undefined cosine.
        ls = b / e if e > EPS else 0.0
        best_p, best_d = alphabet[0], np.inf
        for p in alphabet:
            dist = abs(p - ls) if p * p * e > EPS else np.inf
            if dist < best_d:
                best_d, best_p = dist, p
        return best_p
    best_p, best_s = alphabet[0], -np.inf
    for p in alphabet:
        den2 = cc + 2.0 * p * d + p * p * e
        if den2 <= EPS:
            s = -np.inf
        else:
            s = (a + p * b) / np.sqrt(den2)
        if s > best_s:
            best_s, best_p = s, p
    return best_p


def beacon_channel(
    L: np.ndarray,
    Lt: np.ndarray,
    w: np.ndarray,
    alphabet: Sequence[float],
    loops: int,
) -> Tuple[np.ndarray, float]:
    """Algorithm 1 for one channel. Returns (q ∈ A^N, scale c).

    Without error correction pass L = Lt = R (QR of X).
    """
    L = np.asarray(L, dtype=np.float64)
    Lt = np.asarray(Lt, dtype=np.float64)
    w = np.asarray(w, dtype=np.float64)
    N = w.shape[0]
    alphabet = [float(p) for p in alphabet]

    q = np.zeros(N)
    u = np.zeros(L.shape[0])  # running L̃ q
    yt = np.zeros(L.shape[0])  # running L_{≤t} w_{≤t}
    # Greedy path-following initialization (ℓ = 0)
    for t in range(N):
        yt = yt + L[:, t] * w[t]
        q[t] = argmax_candidate(yt, u, Lt[:, t], alphabet)
        u = u + Lt[:, t] * q[t]

    # Cyclic refinement sweeps (ℓ = 1..loops)
    y = yt  # = L w
    for _ in range(loops):
        for t in range(N):
            u = u - Lt[:, t] * q[t]
            q[t] = argmax_candidate(y, u, Lt[:, t], alphabet)
            u = u + Lt[:, t] * q[t]

    den = float(u @ u)
    c = float(y @ u) / den if den > EPS else 0.0
    return q.astype(np.float32), np.float32(c)


def beacon_objective(L, Lt, w, q) -> float:
    """cos∠(Lw, L̃q) — the quantity Prop 3.1 proves monotone."""
    y = np.asarray(L, np.float64) @ np.asarray(w, np.float64)
    u = np.asarray(Lt, np.float64) @ np.asarray(q, np.float64)
    ny, nu = np.linalg.norm(y), np.linalg.norm(u)
    if ny <= EPS or nu <= EPS:
        return 0.0
    return float(y @ u / (ny * nu))


def beacon_layer(
    X: np.ndarray,
    Xt: np.ndarray,
    W: np.ndarray,
    alphabet: Sequence[float],
    loops: int,
    centering: bool = False,
) -> np.ndarray:
    """Quantize a whole layer; returns the dequantized Q·Diag(s) (+ mean row
    if centering). X = Xt gives the no-error-correction variant."""
    X = np.asarray(X, np.float64)
    Xt = np.asarray(Xt, np.float64)
    W = np.asarray(W, np.float64)
    N, Np = W.shape

    if centering:
        z_w = W.mean(axis=0)  # column means, R^{N'}
        W = W - np.ones((N, 1)) @ z_w[None, :]

    U, R = np.linalg.qr(Xt, mode="reduced")
    L = U.T @ X
    Lt = R

    out = np.empty((N, Np), dtype=np.float64)
    for j in range(Np):
        q, c = beacon_channel(L, Lt, W[:, j], alphabet, loops)
        out[:, j] = float(c) * q

    if centering:
        ones = np.ones(N)
        xt1 = Xt @ ones
        den = float(xt1 @ xt1)
        z_scale = float((X @ ones) @ xt1) / den if den > EPS else 1.0
        out = out + np.ones((N, 1)) @ (z_scale * z_w)[None, :]
    return out.astype(np.float32)


# --------------------------------------------------------------------------
# Baselines
# --------------------------------------------------------------------------
def _levels(bits: float) -> int:
    return {158: 3, 258: 6}.get(int(round(bits * 100)), int(2 ** round(bits)))


def minmax_scale(w: np.ndarray, bits: float) -> Tuple[float, float]:
    """Asymmetric per-channel min-max grid: returns (scale c, zero z) such
    that the grid is {c*(z+k) : k=0..levels-1} (paper §1 notation)."""
    levels = _levels(bits)
    lo, hi = float(w.min()), float(w.max())
    c = (hi - lo) / (levels - 1)
    if c <= EPS:
        return 1.0, 0.0
    z = lo / c
    return c, z


def rtn_channel(w: np.ndarray, bits: float) -> np.ndarray:
    """Round-to-nearest on the min-max grid (the Q operator of §1)."""
    levels = _levels(bits)
    c, z = minmax_scale(w, bits)
    k = np.clip(np.round(np.asarray(w, np.float64) / c - z), 0, levels - 1)
    return (c * (k + z)).astype(np.float32)


def gptq_layer(
    X: np.ndarray, W: np.ndarray, bits: float, damp: float = 0.01
) -> np.ndarray:
    """GPTQ (OPTQ) with asymmetric per-channel min-max grid.

    Sequential row rounding with Hessian-based error feedback:
      H = XᵀX + λI; process t = 0..N-1 using the Cholesky factor of H⁻¹.
    Reference: Frantar et al. 2022 — exact (unblocked) formulation, fine for
    the small N on this testbed.
    """
    X = np.asarray(X, np.float64)
    W = np.asarray(W, np.float64).copy()
    N, Np = W.shape
    H = X.T @ X
    lam = damp * float(np.mean(np.diag(H))) + 1e-10
    H = H + lam * np.eye(N)
    Hinv = np.linalg.inv(H)
    # Upper Cholesky factor with Hinv = Ucᵀ·Uc (torch's cholesky(·, upper=True)
    # used by the reference GPTQ implementation): Uc = chol(Hinv)ᵀ.
    Uc = np.linalg.cholesky(Hinv).T
    levels = _levels(bits)
    scales = np.empty(Np)
    zeros = np.empty(Np)
    for j in range(Np):
        scales[j], zeros[j] = minmax_scale(W[:, j], bits)

    Q = np.zeros_like(W)
    for t in range(N):
        w_row = W[t, :]
        k = np.clip(np.round(w_row / scales - zeros), 0, levels - 1)
        q_row = scales * (k + zeros)
        Q[t, :] = q_row
        err = (w_row - q_row) / Uc[t, t]
        if t + 1 < N:
            W[t + 1 :, :] -= np.outer(Uc[t, t + 1 :], err)
    return Q.astype(np.float32)


def comq_layer(
    X: np.ndarray, W: np.ndarray, bits: float, loops: int = 4
) -> np.ndarray:
    """COMQ-style baseline: cyclic coordinate descent on ||X(w − v)||² where
    v_t is constrained to the *fixed* per-channel min-max grid (scale chosen
    once up front — the contrast with Beacon's integrated scale selection).
    """
    X = np.asarray(X, np.float64)
    W = np.asarray(W, np.float64)
    N, Np = W.shape
    G = X.T @ X  # gram matrix
    gdiag = np.diag(G).copy()
    gdiag[gdiag <= EPS] = 1.0
    levels = _levels(bits)

    Q = np.empty_like(W)
    for j in range(Np):
        w = W[:, j]
        c, z = minmax_scale(w, bits)
        grid = c * (np.arange(levels) + z)
        v = rtn_channel(w, bits).astype(np.float64)
        r = G @ (w - v)  # residual gradient
        for _ in range(loops):
            for t in range(N):
                opt = v[t] + r[t] / gdiag[t]  # unconstrained coord optimum
                vt = grid[int(np.argmin(np.abs(grid - opt)))]
                if vt != v[t]:
                    r -= G[:, t] * (vt - v[t])
                    v[t] = vt
        Q[:, j] = v
    return Q.astype(np.float32)


def layer_recon_error(X, W, Q) -> float:
    """||XW − XQ||_F / ||XW||_F — the metric of eq. (1)."""
    X = np.asarray(X, np.float64)
    num = np.linalg.norm(X @ (np.asarray(W, np.float64) - np.asarray(Q, np.float64)))
    den = np.linalg.norm(X @ np.asarray(W, np.float64)) + EPS
    return float(num / den)
