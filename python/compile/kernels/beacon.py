"""L1: the Beacon inner loop as a Pallas kernel.

One program instance per *channel* (grid = (N',)): the GPU analogue in the
paper's setting would be one threadblock per channel; here each program keeps
the square factor L̃ = R and the running residual u = L̃q resident in VMEM
and performs the greedy initialization plus K cyclic refinement sweeps
(Algorithm 1). The alphabet argmax is vectorized over the |A| candidates
using the 5-scalar expansion of cos∠ (see DESIGN.md §2 / kernels/ref.py).

Lowered with ``interpret=True`` so the whole thing becomes plain HLO
(while-loops + vector ops) executable by the CPU PJRT client loaded from
Rust. On a real TPU the same kernel would compile via Mosaic with the
BlockSpecs below (VMEM analysis in DESIGN.md §Perf).
"""

from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-12
NEG_INF = -1e30


def _argmax_candidate(y, u, col, alph):
    """argmax_{p in A} cos∠(y, u + col*p); first-max tie-break (ascending
    alphabet order), zero-denominator candidates score -inf."""
    a = jnp.dot(y, u)
    b = jnp.dot(y, col)
    cc = jnp.dot(u, u)
    d = jnp.dot(u, col)
    e = jnp.dot(col, col)
    den2 = cc + 2.0 * alph * d + alph * alph * e
    num = a + alph * b
    score = jnp.where(
        den2 > EPS, num * jax.lax.rsqrt(jnp.maximum(den2, EPS)), NEG_INF
    )
    # degenerate u = 0: every same-sign candidate has the same cosine, and
    # f32 rsqrt would break the tie non-deterministically vs the f64 oracle.
    # Deterministic rule (shared with ref.py): nearest to the least-squares
    # coefficient b/e.
    ls = b / jnp.maximum(e, EPS)
    dist = jnp.where(alph * alph * e > EPS, jnp.abs(alph - ls), jnp.inf)
    return jnp.where(
        cc > EPS,
        alph[jnp.argmax(score)],
        alph[jnp.argmin(dist)],
    )


def _beacon_kernel(l_ref, lt_ref, w_ref, alph_ref, loops_ref, q_ref, c_ref, *, n):
    L = l_ref[...]          # [N, N]  (VMEM resident)
    Lt = lt_ref[...]        # [N, N]
    w = w_ref[...][:, 0]    # [N]     (this program's channel)
    alph = alph_ref[...]    # [|A|]   (candidate grid, ascending; pad by
                            #          repeating the max — argmax is
                            #          first-occurrence so padding is inert)
    loops = loops_ref[0]    # scalar i32 — K, the number of sweeps

    zeros = jnp.zeros((n,), jnp.float32)

    # --- greedy path-following init (ℓ = 0) --------------------------------
    def greedy_step(t, carry):
        yt, u, q = carry
        yt = yt + L[:, t] * w[t]
        p = _argmax_candidate(yt, u, Lt[:, t], alph)
        return yt, u + Lt[:, t] * p, q.at[t].set(p)

    y, u, q = jax.lax.fori_loop(0, n, greedy_step, (zeros, zeros, zeros))

    # --- K cyclic refinement sweeps (ℓ = 1..loops) -------------------------
    def sweep_step(i, carry):
        u, q = carry
        t = i % n
        u = u - Lt[:, t] * q[t]
        p = _argmax_candidate(y, u, Lt[:, t], alph)
        return u + Lt[:, t] * p, q.at[t].set(p)

    u, q = jax.lax.fori_loop(0, loops * n, sweep_step, (u, q))  # dynamic bound -> while-loop

    # --- integrated scale (Prop 2.1): c = ⟨Lw, L̃q⟩ / ||L̃q||² -------------
    den = jnp.dot(u, u)
    c = jnp.where(den > EPS, jnp.dot(y, u) / jnp.maximum(den, EPS), 0.0)
    q_ref[...] = q[:, None]
    c_ref[...] = c[None]


def beacon_layer_raw(L, Lt, W, alph, loops):
    """Traceable core: quantize all channels of a layer.

    Returns (Q[N,N'] ∈ A, s[N']). ``alph`` is the ascending candidate grid
    (pad with repeats of the max to reuse one AOT artifact across bit
    widths); ``loops`` is a scalar i32 array — K, traced so one artifact
    serves every sweep count.

    L, Lt: the square factors (UᵀX and R); pass L = Lt = R for the
    no-error-correction variant. W[N, N'] are the (possibly centered)
    weights.
    """
    n, np_ = W.shape
    k = alph.shape[0]
    kernel = partial(_beacon_kernel, n=n)
    q, c = pl.pallas_call(
        kernel,
        grid=(np_,),
        in_specs=[
            pl.BlockSpec((n, n), lambda j: (0, 0)),   # L broadcast
            pl.BlockSpec((n, n), lambda j: (0, 0)),   # L̃ broadcast
            pl.BlockSpec((n, 1), lambda j: (0, j)),   # this channel
            pl.BlockSpec((k,), lambda j: (0,)),       # alphabet
            pl.BlockSpec((1,), lambda j: (0,)),       # loops (scalar)
        ],
        out_specs=[
            pl.BlockSpec((n, 1), lambda j: (0, j)),
            pl.BlockSpec((1,), lambda j: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, np_), jnp.float32),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
        ],
        interpret=True,
    )(
        L.astype(jnp.float32),
        Lt.astype(jnp.float32),
        W.astype(jnp.float32),
        alph.astype(jnp.float32),
        loops.astype(jnp.int32),
    )
    return q, c


@partial(jax.jit, static_argnames=("alphabet", "loops"))
def beacon_layer(L, Lt, W, *, alphabet: Sequence[float], loops: int):
    """Python-side convenience wrapper with a static alphabet/loop count."""
    alph = jnp.asarray(sorted(alphabet), jnp.float32)
    return beacon_layer_raw(L, Lt, W, alph, jnp.asarray([loops], jnp.int32))


def beacon_layer_dequant(L, Lt, W, *, alphabet, loops):
    """Convenience: returns the dequantized weights Q·Diag(s)."""
    q, c = beacon_layer(L, Lt, W, alphabet=tuple(alphabet), loops=loops)
    return q * c[None, :]
