"""Build-time trainer for the synthetic ViT (DESIGN.md §3 substitution).

Trains the configured ViT on the deterministic 'structured blobs' task with
hand-rolled Adam (no optax in this environment). Runs ONCE during
``make artifacts``; the resulting weights are the full-precision model that
the Rust coordinator quantizes. Python never runs at serving/quantization
time.
"""

from __future__ import annotations

import time
from functools import partial
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from .common import ViTConfig, param_spec
from .model import forward, init_params

TRAIN_SEED = 1


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def make_step(cfg: ViTConfig, lr: float = 1e-3):
    def loss_fn(params, images, labels):
        return cross_entropy(forward(cfg, params, images), labels)

    @jax.jit
    def step(params, m, v, t, images, labels):
        l, g = jax.value_and_grad(loss_fn)(params, images, labels)
        b1, b2, eps = 0.9, 0.999, 1e-8
        new_p, new_m, new_v = [], [], []
        for p, mi, vi, gi in zip(params, m, v, g):
            mi = b1 * mi + (1 - b1) * gi
            vi = b2 * vi + (1 - b2) * jnp.square(gi)
            mhat = mi / (1 - b1 ** t)
            vhat = vi / (1 - b2 ** t)
            new_p.append(p - lr * mhat / (jnp.sqrt(vhat) + eps))
            new_m.append(mi)
            new_v.append(vi)
        return l, new_p, new_m, new_v

    return step


def accuracy(cfg: ViTConfig, params, images, labels, batch: int = 256) -> float:
    correct = 0
    fwd = jax.jit(lambda ps, im: forward(cfg, ps, im))
    for i in range(0, len(images), batch):
        logits = fwd(params, images[i : i + batch])
        correct += int(jnp.sum(jnp.argmax(logits, -1) == labels[i : i + batch]))
    return correct / len(images)


def train(
    cfg: ViTConfig,
    steps: int = 600,
    batch: int = 64,
    train_count: int = 4096,
    lr: float = 1e-3,
    seed: int = 0,
    verbose: bool = True,
) -> List[np.ndarray]:
    images, labels = data_mod.generate(cfg, TRAIN_SEED, train_count)
    images = jnp.asarray(images)
    labels = jnp.asarray(labels)
    params = [jnp.asarray(p) for p in init_params(cfg, seed)]
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    step = make_step(cfg, lr)
    t0 = time.time()
    for t in range(1, steps + 1):
        idx = np.arange((t - 1) * batch, t * batch) % train_count
        l, params, m, v = step(params, m, v, float(t), images[idx], labels[idx])
        if verbose and (t % 100 == 0 or t == 1):
            print(f"  step {t:4d}  loss {float(l):.4f}  ({time.time()-t0:.1f}s)")
    if verbose:
        acc = accuracy(cfg, params, images[:1024], labels[:1024])
        print(f"  train accuracy (first 1024): {acc:.4f}")
    return [np.asarray(p) for p in params]
