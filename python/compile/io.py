"""Flat binary tensor-bundle format ("WTS1") shared with Rust.

Layout (little endian), mirrored by ``rust/src/model/store.rs``:

    magic  b"WTS1"
    u32    n_tensors
    per tensor:
      u32   name_len, name bytes (utf-8)
      u32   ndim, u32 dims[ndim]
      f32   data[prod(dims)]
"""

from __future__ import annotations

import struct
from typing import Dict, List, Sequence, Tuple

import numpy as np

MAGIC = b"WTS1"


def save_tensors(path: str, tensors: Sequence[Tuple[str, np.ndarray]]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr, dtype="<f4")
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
            arr.tofile(f)


def load_tensors(path: str) -> List[Tuple[str, np.ndarray]]:
    out = []
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, f"bad magic in {path}"
        (n,) = struct.unpack("<I", f.read(4))
        for _ in range(n):
            (ln,) = struct.unpack("<I", f.read(4))
            name = f.read(ln).decode("utf-8")
            (nd,) = struct.unpack("<I", f.read(4))
            dims = struct.unpack(f"<{nd}I", f.read(4 * nd))
            cnt = int(np.prod(dims)) if nd else 1
            arr = np.fromfile(f, dtype="<f4", count=cnt).reshape(dims)
            out.append((name, arr))
    return out


def load_tensor_dict(path: str) -> Dict[str, np.ndarray]:
    return dict(load_tensors(path))
